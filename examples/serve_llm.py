"""Serving example: the batched inference server on an LM workload.

Requests (fixed-length token prompts) flow through the real serving stack —
:class:`repro.serving.InferenceServer` with an :class:`~repro.serving.LMAdapter`
(batched prefill + greedy decode with donated KV caches, ``launch/serve.py``),
paced by the open-loop :class:`~repro.serving.LoadGenerator` — and the run
prints the ``repro.serve/v1`` latency/throughput summary the CI serve smoke
asserts on.

Run:  PYTHONPATH=src python examples/serve_llm.py --arch yi-6b --n-new 16
"""
import argparse
import time

import jax
import numpy as np

from repro import configs, serving
from repro.data import make_lm_tokens
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4,
                    help="server max_batch (compile-once shape)")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--qps", type=float, default=40.0)
    args = ap.parse_args()

    cfg = configs.reduced(configs.get(args.arch))
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    params = tf.init_params(cfg, jax.random.key(0))
    prompts, _ = make_lm_tokens(cfg.vocab, args.requests, args.prompt_len,
                                seed=1)
    prompts = np.asarray(prompts, np.int32)

    metrics = serving.ServingMetrics(offered_qps=args.qps)
    adapter = serving.LMAdapter(cfg, args.batch, args.prompt_len, args.n_new)
    server = serving.InferenceServer(adapter, params, metrics=metrics)
    gen = serving.LoadGenerator(server, prompts, args.qps, metrics=metrics)

    t0 = time.perf_counter()
    server.start()
    try:
        gen.run(n_requests=args.requests)
        errors = gen.drain()
    finally:
        server.stop()
    dt = time.perf_counter() - t0

    doc = metrics.summary()
    print(f"arch={cfg.name} (reduced)  max_batch={args.batch} "
          f"prompt={args.prompt_len} new={args.n_new}")
    # replay a few requests synchronously so the output is showable
    for i in range(min(args.requests, args.batch)):
        out = server.submit(prompts[i])
        server.step(block=True)
        print(f"  req{i}: prompt={list(map(int, prompts[i][:8]))}... "
              f"-> generated={list(map(int, out.wait(30.0)))}")
    lat = doc["latency_us"]
    print(f"{doc['tokens']['generated']} tokens for {doc['requests']['served']}"
          f" requests in {dt:.2f}s ({doc['tokens']['generated'] / dt:.1f}"
          f" tok/s on 1 CPU core, {errors} errors)")
    print(f"latency p50={lat['p50'] / 1e3:.1f}ms p99={lat['p99'] / 1e3:.1f}ms "
          f"mean_fill={doc['batches']['mean_fill']:.2f}")


if __name__ == "__main__":
    main()
