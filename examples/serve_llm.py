"""Serving example: batched prefill + greedy decode with KV caches.

Serves a reduced assigned architecture with a batch of token requests —
demonstrating the prefill/decode split the decode_32k / long_500k dry-run
shapes exercise at production scale.

Run:  PYTHONPATH=src python examples/serve_llm.py --arch yi-6b --n-new 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import make_lm_tokens
from repro.launch.serve import greedy_generate
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--n-new", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.reduced(configs.get(args.arch))
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    params = tf.init_params(cfg, jax.random.key(0))
    prompts, _ = make_lm_tokens(cfg.vocab, args.batch, args.prompt_len, seed=1)
    prompts = jnp.asarray(prompts)

    cache_len = args.prompt_len + args.n_new + 8
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, prompts, args.n_new, cache_len)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} (reduced)  batch={args.batch} "
          f"prompt={args.prompt_len} new={args.n_new}")
    for i in range(args.batch):
        print(f"  req{i}: prompt={list(map(int, prompts[i][:8]))}... "
              f"-> generated={list(map(int, out[i]))}")
    print(f"{args.batch * args.n_new} tokens in {dt:.2f}s "
          f"({args.batch * args.n_new / dt:.1f} tok/s on 1 CPU core)")


if __name__ == "__main__":
    main()
