"""End-to-end driver: federated training of an LLM on the datacenter mesh.

Trains a ~125M-param xLSTM (or any --arch, reduced with --reduced) for a few
hundred steps with the THGS + secure-aggregation train step — the cross-silo
deployment of the paper (each mesh 'pod'/'data' group = one financial
institution). On this CPU container it runs the REDUCED config on a small fake
mesh; on real hardware the same script drives the production mesh.

Run:  PYTHONPATH=src python examples/federated_llm_training.py \
          --arch xlstm-125m --reduced --steps 50
"""
import argparse
import dataclasses
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import checkpoint, configs
from repro.core import costs
from repro.core.types import SecureAggConfig, THGSConfig
from repro.data import make_lm_tokens
from repro.launch import shardings as shd
from repro.launch.mesh import logical_rules, make_debug_mesh
from repro.launch.train import fl_leaf_plan, make_fl_train_step
from repro.models import transformer as tf
from repro.models.sharding import logical_axis_rules
from repro.sim import CommLedger, mib


def step_wire_record(step_t, params, thgs, sa, n_fed, n_blocks):
    """One CommRecord for a datacenter FL step, mirroring the step builder's
    static plan: per leaf, ``nb`` blocks of ``kb`` top-k slots plus
    ``k_mask_block`` mask slots per block toward each of the n_fed-1 peers
    (launch/train.py::fl_leaf_plan + the Eq. 4 per-block mask count)."""
    pshapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    plan = fl_leaf_plan(pshapes, thgs, n_blocks)
    sizes = [x.size for x in jax.tree_util.tree_leaves(pshapes)]
    ks, k_masks = [], []
    for size, (kb, nb) in zip(sizes, plan):
        ks.append(nb * kb)
        k_masks.append(
            nb * max(1, int(size * sa.mask_ratio / n_fed / nb))
            if (sa.enabled and n_fed >= 2) else 0)
    return costs.round_record(step_t, sum(sizes), ks, k_masks,
                              n_clients=n_fed, bits=costs.TPU_BITS)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--ckpt", default="/tmp/repro_fl_ckpt")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    mesh = make_debug_mesh(2, 2, multi_pod=True)   # (pod=2, data=2, model=2)
    fed_axis = "pod"
    rules = logical_rules(mesh, fed_axis=fed_axis)

    key = jax.random.key(0)
    params = tf.init_params(cfg, key)
    pshapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    params = jax.device_put(params, shd.named(
        shd.param_specs(pshapes, rules, mesh), mesh))
    residuals = jax.device_put(
        jax.tree_util.tree_map(
            lambda x: jnp.zeros((2,) + x.shape, jnp.bfloat16), params),
        NamedSharding(mesh, P(fed_axis)))

    thgs = THGSConfig(s0=0.05, alpha=0.9, s_min=0.01)
    sa = SecureAggConfig(mask_ratio=0.01)
    step = make_fl_train_step(cfg, mesh, fed_axis, thgs, sa, lr=args.lr)
    # each institution's private corpus -> distinct token stream statistics
    toks, labels = make_lm_tokens(cfg.vocab, args.batch, args.seq, seed=0)
    batch = jax.device_put(
        {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)},
        NamedSharding(mesh, P(("pod", "data"), None)))

    # resume from the latest checkpoint when one exists; the THGS error-
    # feedback residuals are part of the training state (dropping them would
    # lose every sparsified-away gradient accumulated so far)
    start = checkpoint.latest_step(args.ckpt) or 0
    if start:
        tree = checkpoint.restore(
            args.ckpt, start,
            like={"params": params, "residuals": residuals})
        params, residuals = tree["params"], tree["residuals"]
        print(f"resumed from {args.ckpt} at step {start}")

    n_fed = 2
    n_blocks = mesh.devices.size // n_fed
    ledger = CommLedger()
    rec = step_wire_record(0, params, thgs, sa, n_fed, n_blocks)

    with logical_axis_rules(mesh, rules):
        jstep = jax.jit(step, donate_argnums=(0, 1))
        for i in range(start, args.steps):
            params, residuals, loss = jstep(params, residuals, batch,
                                            jax.random.key(i))
            ledger.record(dataclasses.replace(rec, round=i))
            if (i + 1) % 10 == 0:
                print(f"step {i+1:4d}  loss={float(loss):.4f}")

    checkpoint.save(args.ckpt, args.steps,
                    {"params": params, "residuals": residuals})
    print(f"checkpoint written to {args.ckpt} "
          f"(step {checkpoint.latest_step(args.ckpt)})")
    t = ledger.totals("tpu")
    print(f"federation exchange (tpu accounting): "
          f"{mib(t['upload_bits']):.1f} MiB uploaded vs "
          f"{mib(t['dense_upload_bits']):.1f} MiB dense "
          f"-> {t['upload_vs_dense']:.1%} ({t['compression_x']:.1f}x)")
    ledger.to_json(os.path.join(args.ckpt, "comm_ledger.json"),
                   extra={"arch": args.arch, "steps": args.steps})


if __name__ == "__main__":
    main()
