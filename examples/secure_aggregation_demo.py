"""Secure-aggregation walkthrough: what the server sees, and why masks cancel.

Reproduces the paper's §4 safety analysis empirically on the batched stream
engine (core/streams.py): two banks' sparsified, masked model updates are
encoded in ONE vmapped program and decoded with ONE fused scatter-add; the
demo shows (1) the server's view of each individual update is masked at the
mask-support positions, (2) the aggregate is exact, (3) when a third bank
drops mid-round the server reconstructs and cancels the survivors' unpaired
masks (Bonawitz recovery), and (4) the dense Bonawitz baseline costs the full
vector while the sparse scheme moves only top-k ∪ mask-support.

Run:  PYTHONPATH=src python examples/secure_aggregation_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import streams
from repro.core.costs import PAPER_BITS
from repro.core.masks import dh_agree
from repro.core.types import SecureAggConfig

def main():
    n = 4096
    k = int(n * 0.02)
    sa = SecureAggConfig(mask_ratio=0.02, seed=2024)
    banks = [0, 1, 2]
    C = len(banks)
    k_mask = sa.k_mask_for(n, C)

    print("1. DH agreement (control plane, once per federation):")
    print(f"   bank0<->bank1 shared secret: {dh_agree(sa.seed, 0, 1):#x} "
          f"(== {dh_agree(sa.seed, 1, 0):#x} from the other side)\n")

    key = jax.random.key(7)
    grads = jnp.stack([jax.random.normal(jax.random.fold_in(key, b), (n,))
                       for b in banks])
    residuals = jnp.zeros_like(grads)
    pair_keys, pair_signs = streams.pair_key_matrix(sa, banks, round_t=0)

    # one jitted program encodes every bank: top-k ∪ mask-support streams
    st, new_res = streams.encode_leaf_batch(
        grads, residuals, k=k, nb=1, m=n, size=n,
        pair_keys=pair_keys, pair_signs=pair_signs, k_mask=k_mask,
        mask_p=sa.p, mask_q=sa.q, leaf_id=0)

    print("2. what the SERVER sees from bank0 (one leaf):")
    idx0 = np.asarray(st.indices[0, 0])
    sent = np.asarray(st.values[0, 0])
    print(f"   {idx0.shape[0]} slots of {n} ({idx0.shape[0]/n:.1%}); "
          f"first 5 values: {sent[:5].round(3)}")
    raw = np.asarray(grads[0])[idx0]
    masked_slots = int((np.abs(sent - raw) > 1e-6).sum())
    print(f"   {masked_slots} slots differ from the raw gradient "
          f"(mask-protected); {idx0.shape[0] - masked_slots} top-k slots are "
          f"clear (paper §4 case 1 — sparsity itself is the cover)\n")

    # one fused scatter-add decodes the whole round; masks cancel exactly
    dense = streams.decode_leaf_batch(st, nb=1, m=n, size=n)
    expected = (grads - new_res).sum(0)
    err = float(jnp.max(jnp.abs(dense - expected)))
    print(f"3. aggregate exactness: max |masked_sum - true_sparse_sum| = {err:.2e}")

    # bank2 drops after mask agreement: the server regenerates the survivors'
    # pair masks toward it and subtracts them (Bonawitz dropout recovery)
    alive = jnp.array([True, True, False])
    dense_drop = streams.decode_leaf_batch(
        st, nb=1, m=n, size=n, alive=alive,
        pair_keys=pair_keys, pair_signs=pair_signs, k_mask=k_mask,
        mask_p=sa.p, mask_q=sa.q, leaf_id=0)
    expected_drop = ((grads - new_res) * alive[:, None]).sum(0)
    err_drop = float(jnp.max(jnp.abs(dense_drop - expected_drop)))
    no_recovery = float(jnp.max(jnp.abs(
        streams.decode_leaf_batch(st, nb=1, m=n, size=n, alive=alive)
        - expected_drop)))
    print(f"4. bank2 drops: survivor sum error {no_recovery:.2f} without "
          f"recovery -> {err_drop:.2e} with reconstructed-mask cancellation")

    # wire payload: the gated self-pair slot (zero value, duplicated index)
    # is not transmitted -> k + (C-1)*k_mask slots per client (Eq. 6)
    k_wire = st.k_total - k_mask
    sparse_bits = 2 * PAPER_BITS.sparse_bits(k_wire)
    dense_bits = 2 * PAPER_BITS.dense_bits(n)
    print(f"\n5. communication: sparse+masked = {sparse_bits/8:.0f} B, "
          f"dense Bonawitz = {dense_bits/8:.0f} B "
          f"-> {dense_bits/sparse_bits:.1f}x reduction")


if __name__ == "__main__":
    main()
