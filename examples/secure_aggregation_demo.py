"""Secure-aggregation walkthrough: what the server sees, and why masks cancel.

Reproduces the paper's §4 safety analysis empirically: two banks exchange
sparsified, masked model updates; the demo shows (1) the server's view of each
individual update is masked at the mask-support positions, (2) the aggregate is
exact, (3) the dense Bonawitz baseline costs the full vector while the sparse
scheme moves only top-k ∪ mask-support.

Run:  PYTHONPATH=src python examples/secure_aggregation_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import PAPER_BITS
from repro.core.masks import client_masks, dh_agree
from repro.core.secure_agg import aggregate_streams, encode_update
from repro.core.types import SecureAggConfig, THGSConfig, tree_zeros_like


def main():
    n = 4096
    thgs = THGSConfig(s0=0.02, alpha=1.0, s_min=0.02, time_varying=False)
    sa = SecureAggConfig(mask_ratio=0.02, seed=2024)
    banks = [0, 1]

    print("1. DH agreement (control plane, once per federation):")
    print(f"   bank0<->bank1 shared secret: {dh_agree(sa.seed, 0, 1):#x} "
          f"(== {dh_agree(sa.seed, 1, 0):#x} from the other side)\n")

    key = jax.random.key(7)
    grads = {b: {"w": jax.random.normal(jax.random.fold_in(key, b), (n,))}
             for b in banks}
    streams, resids = {}, {}
    for b in banks:
        streams[b], resids[b] = encode_update(
            grads[b], tree_zeros_like(grads[b]), [int(n * 0.02)], thgs, sa,
            client=b, participants=banks, round_t=0)

    s0 = streams[0][0]
    print("2. what the SERVER sees from bank0 (one leaf):")
    print(f"   {s0.k} slots of {n} ({s0.k/n:.1%}); "
          f"first 5 values: {np.asarray(s0.values[:5]).round(3)}")
    k_mask = sa.k_mask_for(n, 2)
    mask = client_masks(sa, 0, banks, 0, 0, n, k_mask)
    raw = np.asarray(grads[0]["w"])[np.asarray(s0.indices)]
    sent = np.asarray(s0.values)
    masked_slots = int((np.abs(sent - raw) > 1e-6).sum())
    print(f"   {masked_slots} slots differ from the raw gradient "
          f"(mask-protected); {s0.k - masked_slots} top-k slots are clear "
          f"(paper §4 case 1 — sparsity itself is the cover)\n")

    agg = aggregate_streams([streams[0], streams[1]], [(n,)], [jnp.float32])
    expected = sum(
        (grads[b]["w"] - resids[b]["w"]) / 2 for b in banks)
    err = float(jnp.max(jnp.abs(agg[0] - expected)))
    print(f"3. aggregate exactness: max |masked_sum - true_sparse_mean| = {err:.2e}")

    sparse_bits = 2 * PAPER_BITS.sparse_bits(s0.k)
    dense_bits = 2 * PAPER_BITS.dense_bits(n)
    print(f"\n4. communication: sparse+masked = {sparse_bits/8:.0f} B, "
          f"dense Bonawitz = {dense_bits/8:.0f} B "
          f"-> {dense_bits/sparse_bits:.1f}x reduction")


if __name__ == "__main__":
    main()
