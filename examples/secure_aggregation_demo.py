"""Secure-aggregation walkthrough: what the server sees, and why masks cancel.

Reproduces the paper's §4 safety analysis empirically on the batched stream
engine (core/streams.py) driven by the repro/secagg round protocol: three
banks run the Bonawitz phase sequence (DH key agreement, Shamir key sharing,
masked upload, unmasking); the demo shows (1) the server's view of each
individual update is masked at the mask-support positions, (2) the aggregate
is exact, (3) when a bank drops mid-round the server reconstructs its DH key
from the survivors' Shamir shares and cancels the unpaired masks, and (4) the
dense Bonawitz baseline costs the full vector while the sparse scheme moves
only top-k ∪ mask-support plus a few control-plane shares.

Run:  PYTHONPATH=src python examples/secure_aggregation_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import streams
from repro.core.costs import PAPER_BITS
from repro.core.masks import dh_agree
from repro.core.types import SecureAggConfig
from repro.secagg import RoundProtocol

def main():
    n = 4096
    k = int(n * 0.02)
    sa = SecureAggConfig(mask_ratio=0.02, seed=2024)
    banks = [0, 1, 2]
    C = len(banks)
    k_mask = sa.k_mask_for(n, C)

    print("1. round protocol setup (control plane):")
    print(f"   DH: bank0<->bank1 shared secret {dh_agree(sa.seed, 0, 1):#x} "
          f"(== {dh_agree(sa.seed, 1, 0):#x} from the other side)")
    proto = RoundProtocol.setup(sa, banks, round_t=0)
    print(f"   Shamir: each bank splits its key into {C} shares, "
          f"threshold t={proto.t} ({proto.n_phase1_shares} shares cross "
          f"the wire)\n")

    key = jax.random.key(7)
    grads = jnp.stack([jax.random.normal(jax.random.fold_in(key, b), (n,))
                       for b in banks])
    residuals = jnp.zeros_like(grads)
    pair_seeds, pair_signs = proto.pair_seed_matrix()

    # one jitted program encodes every bank: top-k ∪ mask-support streams,
    # all pair masks generated counter-based in one fused pass
    st, new_res = streams.encode_leaf_batch(
        grads, residuals, k=k, nb=1, m=n, size=n,
        pair_seeds=pair_seeds, pair_signs=pair_signs, k_mask=k_mask,
        mask_p=sa.p, mask_q=sa.q, leaf_id=0)

    print("2. what the SERVER sees from bank0 (one leaf):")
    idx0 = np.asarray(st.indices[0, 0])
    sent = np.asarray(st.values[0, 0])
    print(f"   {idx0.shape[0]} slots of {n} ({idx0.shape[0]/n:.1%}); "
          f"first 5 values: {sent[:5].round(3)}")
    raw = np.asarray(grads[0])[idx0]
    masked_slots = int((np.abs(sent - raw) > 1e-6).sum())
    print(f"   {masked_slots} slots differ from the raw gradient "
          f"(mask-protected); {idx0.shape[0] - masked_slots} top-k slots are "
          f"clear (paper §4 case 1 — sparsity itself is the cover)\n")

    # one fused scatter-add decodes the whole round; masks cancel exactly
    dense = streams.decode_leaf_batch(st, nb=1, m=n, size=n)
    expected = (grads - new_res).sum(0)
    err = float(jnp.max(jnp.abs(dense - expected)))
    print(f"3. aggregate exactness: max |masked_sum - true_sparse_sum| = {err:.2e}")

    # bank2 drops after mask agreement: the survivors hand the server their
    # Shamir shares of bank2's key; the server reconstructs it, re-derives
    # the pair seeds and subtracts the unpaired masks (Bonawitz recovery)
    alive = jnp.array([True, True, False])
    recovered_seeds = proto.recover_seeds(survivors=[0, 1], dropped=[2])
    dense_drop = streams.decode_leaf_batch(
        st, nb=1, m=n, size=n, alive=alive,
        pair_seeds=recovered_seeds, pair_signs=pair_signs, k_mask=k_mask,
        mask_p=sa.p, mask_q=sa.q, leaf_id=0)
    expected_drop = ((grads - new_res) * alive[:, None]).sum(0)
    err_drop = float(jnp.max(jnp.abs(dense_drop - expected_drop)))
    no_recovery = float(jnp.max(jnp.abs(
        streams.decode_leaf_batch(st, nb=1, m=n, size=n, alive=alive)
        - expected_drop)))
    n_rec = proto.n_recovery_shares(1)
    print(f"4. bank2 drops: survivor sum error {no_recovery:.2f} without "
          f"recovery -> {err_drop:.2e} after reconstructing its key from "
          f"{n_rec} survivor shares")

    # wire payload: the gated self-pair slot (zero value, duplicated index)
    # is not transmitted -> k + (C-1)*k_mask slots per client (Eq. 6).
    # All three arms are whole-cohort uploads for the round (C banks'
    # gradients, all C·(C-1) phase-1 shares plus the recovery shares bank2's
    # drop just cost) so the ratio compares like scopes.
    k_wire = st.k_total - k_mask
    sparse_bits = C * PAPER_BITS.sparse_bits(k_wire)
    share_bits = ((proto.n_phase1_shares + proto.n_recovery_shares(1))
                  * PAPER_BITS.share_bits())
    dense_bits = C * PAPER_BITS.dense_bits(n)
    print(f"\n5. communication: sparse+masked = {sparse_bits/8:.0f} B "
          f"(+ {share_bits/8:.0f} B Shamir shares), "
          f"dense Bonawitz = {dense_bits/8:.0f} B "
          f"-> {dense_bits/(sparse_bits + share_bits):.1f}x reduction")


if __name__ == "__main__":
    main()
