"""Quickstart: THGS + sparse-mask secure aggregation in 60 lines.

Trains the paper's MNIST-MLP federated across 10 clients (Non-IID-4) with the
efficient+secure pipeline, and prints the round-by-round accuracy and the
upload compression vs conventional FedAvg.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fedavg import init_state, run_round
from repro.core.types import FedConfig, SecureAggConfig, THGSConfig
from repro.data import MNIST, client_batches, make_dataset, noniid_label_k
from repro.models.paper_models import MNIST_MLP, accuracy, cross_entropy_loss


def main():
    # --- data: synthetic MNIST stand-in, Non-IID-4 across 10 clients
    x, y = make_dataset(MNIST, 4000, seed=0)
    xt, yt = make_dataset(MNIST, 800, seed=1, train=False)
    parts = noniid_label_k(y, n_clients=10, k=4, seed=0)

    # --- the paper's two mechanisms
    thgs = THGSConfig(s0=0.05, alpha=0.9, s_min=0.01)     # Alg. 1 / Eq. 1-2
    sa = SecureAggConfig(mask_ratio=0.01)                 # Alg. 2 / Eq. 3-5
    fed = FedConfig(n_clients=10, clients_per_round=5, local_steps=5,
                    local_batch=50, local_lr=0.05, rounds=30)

    params = MNIST_MLP.init(jax.random.key(0))
    loss_fn = cross_entropy_loss(MNIST_MLP)
    state = init_state(params, fed)

    rs = np.random.RandomState(0)
    for r in range(fed.rounds):
        chosen = rs.choice(fed.n_clients, fed.clients_per_round, replace=False)
        batches = {}
        for c in chosen:
            xb, yb = client_batches(x, y, parts[int(c)], fed.local_batch,
                                    fed.local_steps, seed=r * 100 + int(c))
            batches[int(c)] = (jnp.asarray(xb), jnp.asarray(yb))
        state = run_round(state, batches, loss_fn, fed, thgs, sa)
        if (r + 1) % 5 == 0:
            acc = accuracy(MNIST_MLP, state.params, xt, yt)
            rec = state.comm_log[-1]
            print(f"round {r+1:3d}  acc={acc:.3f}  "
                  f"upload={rec.upload_bits/8/2**20:.2f} MiB "
                  f"({rec.compression:.1f}x smaller than FedAvg)")

    total_up = sum(r.upload_bits for r in state.comm_log)
    total_dense = sum(r.dense_upload_bits for r in state.comm_log)
    print(f"\ntotal upload: {total_up/8/2**20:.1f} MiB vs FedAvg "
          f"{total_dense/8/2**20:.1f} MiB -> {total_up/total_dense:.1%} "
          f"(paper: 2.9%-18.9% at s=0.01)")


if __name__ == "__main__":
    main()
