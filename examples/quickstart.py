"""Quickstart: THGS + sparse-mask secure aggregation through the sim engine.

Trains the paper's MNIST-MLP federated across 10 clients (Non-IID-4) with the
efficient+secure pipeline — one `repro.sim` preset — and prints the round-by-
round accuracy plus the upload compression vs conventional FedAvg under both
bit accountings (paper 64-bit elements / float32 TPU wire format).

Run:  PYTHONPATH=src python examples/quickstart.py [--rounds N]
"""
import argparse

from repro.sim import Simulation, mib, presets


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=None,
                    help="shrink/extend the run (default: the preset's 30)")
    args = ap.parse_args()

    cfg = presets.get("quickstart")
    if args.rounds:
        cfg = cfg.replace(rounds=args.rounds,
                          eval_every=min(cfg.eval_every, args.rounds))

    def show(round_t, info):
        if "acc" in info:
            rec = info["record"]
            print(f"round {round_t + 1:3d}  acc={info['acc']:.3f}  "
                  f"upload={mib(rec.upload_bits):.2f} MiB "
                  f"({rec.compression:.1f}x smaller than FedAvg)")

    res = Simulation(cfg).run(hooks=[show])

    t = res.ledger.totals("paper")
    print(f"\ntotal upload: {t['upload_mib']:.1f} MiB vs FedAvg "
          f"{t['dense_upload_mib']:.1f} MiB -> {t['upload_vs_dense']:.1%} "
          f"(paper: 2.9%-18.9% at s=0.01)")
    t = res.ledger.totals("tpu")
    print(f"tpu accounting: {t['upload_mib']:.1f} MiB vs "
          f"{t['dense_upload_mib']:.1f} MiB -> {t['upload_vs_dense']:.1%}")


if __name__ == "__main__":
    main()
