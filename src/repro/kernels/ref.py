"""Pure-jnp oracles for every Pallas kernel (the allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """[B,T,H,hd] x [B,S,Hkv,hd] GQA attention, f32 math."""
    b, t, h, hd = q.shape
    s = k.shape[1]
    group = h // k.shape[2]
    kf = jnp.repeat(k, group, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=2).astype(jnp.float32)
    scores = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32), kf) / (hd ** 0.5)
    q_pos = jnp.arange(t)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, vf)
    return out.astype(q.dtype)


def thgs_sparsify_ref(g, residual, threshold):
    """Fused THGS threshold step: acc = g + residual; split at |acc| > delta."""
    acc = (g.astype(jnp.float32) + residual.astype(jnp.float32))
    keep = jnp.abs(acc) > threshold
    sparse = jnp.where(keep, acc, 0.0)
    new_resid = jnp.where(keep, 0.0, acc)
    return sparse.astype(g.dtype), new_resid.astype(residual.dtype)


def _mix32(x: jax.Array) -> jax.Array:
    """murmur3-style avalanche of uint32 lanes (the kernel uses the same)."""
    x = x.astype(jnp.uint32)
    x ^= x >> 16
    x *= jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x *= jnp.uint32(0x846CA68B)
    x ^= x >> 16
    return x


def stream_scatter_add_ref(indices, values, size: int):
    """Scatter-add a flat stream into dense f32[size]; out-of-range dropped."""
    idx = indices.reshape(-1).astype(jnp.int32)
    val = values.reshape(-1).astype(jnp.float32)
    valid = (idx >= 0) & (idx < size)
    return jnp.zeros((size,), jnp.float32).at[
        jnp.where(valid, idx, 0)].add(jnp.where(valid, val, 0.0))


# Domain-separation salts for the counter-based pair-mask streams: one murmur
# stream for support indices, one for values, one for per-leaf seed folding.
# Both endpoints of a pair derive the same uint32 seed (repro/secagg), so the
# same counters yield the same (idx, |val|) draws and the signed values cancel.
IDX_SALT = 0x9E3779B9
VAL_SALT = 0x85EBCA6B
LEAF_SALT = 0xA511E9B3


def fold_leaf_seed(seeds: jax.Array, leaf_id) -> jax.Array:
    """Fold a (traced or static) leaf id into uint32 pair seeds.

    In-trace equivalent of deriving an independent counter stream per leaf;
    matches the kernel and the host reference (masks.pair_mask) bit for bit.
    """
    leaf = jnp.asarray(leaf_id).astype(jnp.uint32)
    return _mix32(jnp.asarray(seeds, jnp.uint32)
                  ^ _mix32(leaf + jnp.uint32(LEAF_SALT)))


def pair_mask_stream_ref(seeds, signs, nb: int, k_mask: int, m: int,
                         *, p: float, q: float):
    """Counter-based sparse pair-mask streams — the engine's mask data plane.

    For each pair seed (uint32[...]) generate ``nb`` blocks of ``k_mask``
    (index, value) slots: ``idx = mix32(mix32(seed^IDX_SALT) + c) % m`` and
    ``val = sign * (p + q * (mix32(mix32(seed^VAL_SALT) + c) >> 8) / 2**24)``
    with flat counter ``c = block * k_mask + slot`` — only the top 24 bits of
    the value draw are used (see the inline comment below; the 2^-24 grid is
    what makes colliding masks cancel bit-exactly in f32). Support indices
    MAY repeat
    (mod-m collisions); both endpoints generate identical duplicates, so every
    slot still cancels in the aggregate, and the unified-stream
    first-occurrence gate transmits the underlying gradient only once
    (tested end-to-end in tests/test_secagg_protocol.py).

    Returns ``(idx int32[..., nb, k_mask], vals f32[..., nb, k_mask])``.
    """
    seeds = jnp.asarray(seeds, jnp.uint32)
    signs = jnp.asarray(signs, jnp.float32)
    c = jnp.arange(nb * k_mask, dtype=jnp.uint32).reshape(nb, k_mask)
    c = c.reshape((1,) * seeds.ndim + (nb, k_mask))
    base_i = _mix32(seeds ^ jnp.uint32(IDX_SALT))[..., None, None]
    base_v = _mix32(seeds ^ jnp.uint32(VAL_SALT))[..., None, None]
    idx = (_mix32(base_i + c) % jnp.uint32(m)).astype(jnp.int32)
    # top 24 bits only: uniforms land on the f32-exact 2^-24 grid, so masks
    # colliding at one dense position still cancel bit-exactly in the
    # scatter-add (32-bit entropy would leave 1-ulp residue on collisions)
    u = (_mix32(base_v + c) >> 8).astype(jnp.float32) / jnp.float32(2**24)
    vals = signs[..., None, None] * (p + q * u)
    return idx, vals


# Domain-separation salts for the distributed-DP streams (core/dp.py,
# DESIGN.md §15): two independent murmur counter streams per client feed a
# Box-Muller transform, and one public stream draws the round's common
# release support. Distinct from IDX/VAL/LEAF_SALT so DP draws never
# collide with the pair-mask draws even under equal seeds.
DP_U1_SALT = 0x94D049BB
DP_U2_SALT = 0xBF58476D
DP_SUP_SALT = 0xC2B2AE35


def dp_support_stream_ref(seeds, nb: int, k: int, m: int):
    """Counter-based PUBLIC common-support indices for the DP data release.

    Under DP noise every client of a round releases gradient values at the
    SAME ``k`` positions per block — drawn here from a seed that is a pure
    function of (dp seed, round, leaf), never of the data. A data-dependent
    support (top-k) would leak through the transmitted indices and would
    spread each client's noise over slots the others don't share; a common
    public support makes the index release free and stacks every survivor's
    noise on every released coordinate (core/dp.py, DESIGN.md §15).

    Same draw discipline as the pair-mask support
    (:func:`pair_mask_stream_ref`): ``idx = mix32(mix32(seed^DP_SUP_SALT)
    + c) % m`` with flat counter ``c = block * k + slot``. Mod-``m``
    collisions MAY repeat an index inside a block; the unified stream's
    first-occurrence gate transmits the underlying gradient once, and the
    duplicate slot just carries one extra noise draw (privacy-conservative).
    Returns int32[..., nb, k].
    """
    seeds = jnp.asarray(seeds, jnp.uint32)
    c = jnp.arange(nb * k, dtype=jnp.uint32).reshape(nb, k)
    c = c.reshape((1,) * seeds.ndim + (nb, k))
    base = _mix32(seeds ^ jnp.uint32(DP_SUP_SALT))[..., None, None]
    return (_mix32(base + c) % jnp.uint32(m)).astype(jnp.int32)


def dp_noise_stream_ref(seeds, nb: int, k: int, *, sigma: float):
    """Counter-based grid-rounded Gaussian noise on the f32-exact 2^-24 grid.

    For each uint32 seed draw ``nb`` blocks of ``k`` noise values with flat
    counter ``c = block * k + slot`` — the same counter discipline as
    :func:`pair_mask_stream_ref`, so a resumed run replays the identical
    stream from (seed, leaf, slot) alone. Two murmur streams give 24-bit
    uniforms ``u1 in (0, 1]`` and ``u2 in [0, 1)``; Box-Muller maps them to a
    standard normal ``z``, and the emitted value is

        ``round(z * sigma * 2**24) * 2**-24``

    — an integer multiple of the mask grid. Pair masks are multiples of the
    same grid (the ``>> 8`` draw above), so masks + noise compose exactly in
    f32 scatter-adds while per-slot partial sums stay below 1 in magnitude
    (2^24 grid units — the identical headroom contract the mask plane has;
    DESIGN.md §15). This is a *rounded continuous* Gaussian — accounted as
    continuous by core/dp.py (the <= 2^-25 rounding perturbation is
    negligible against any practical sigma) — NOT the Canonne-Kamath-Steinke
    discrete Gaussian mechanism. Returns f32[..., nb, k].
    """
    seeds = jnp.asarray(seeds, jnp.uint32)
    c = jnp.arange(nb * k, dtype=jnp.uint32).reshape(nb, k)
    c = c.reshape((1,) * seeds.ndim + (nb, k))
    b1 = _mix32(seeds ^ jnp.uint32(DP_U1_SALT))[..., None, None]
    b2 = _mix32(seeds ^ jnp.uint32(DP_U2_SALT))[..., None, None]
    # u1 in (0, 1]: +1 keeps log(u1) finite; u2 in [0, 1) — top 24 bits only,
    # matching the mask draw's grid discipline
    u1 = ((_mix32(b1 + c) >> 8).astype(jnp.float32) + 1.0) / jnp.float32(2**24)
    u2 = (_mix32(b2 + c) >> 8).astype(jnp.float32) / jnp.float32(2**24)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(
        jnp.float32(2.0 * 3.141592653589793) * u2)
    q = jnp.round(z * jnp.float32(sigma) * jnp.float32(2**24))
    return q * jnp.float32(2.0 ** -24)


# --------------------------------------------------- wire-format bit packing
# Fixed-width bit packing of uint fields into uint32 words — the data plane of
# the StreamCodec wire stage (core/codecs.py, DESIGN.md §12). Rows are packed
# in chunks of 32 slots: a 32-slot chunk at field width ``w`` occupies exactly
# ``32*w`` bits = ``w`` words, so chunks never straddle a word boundary and the
# Pallas kernel (kernels/pack.py) can grid over (row tiles, chunks) with a
# statically-windowed output block. These refs use the identical per-chunk
# math, so kernel/ref parity is bit-exact by construction (pinned in
# tests/test_kernels.py).

PACK_CHUNK = 32  # slots per chunk; chunk bit-width = 32*width = width words


def _pack_chunk(u: jax.Array, width: int) -> jax.Array:
    """uint32[..., 32] fields (each < 2**width) -> uint32[..., width] words."""
    pos = jnp.arange(PACK_CHUNK, dtype=jnp.uint32) * jnp.uint32(width)
    j1 = (pos // 32).astype(jnp.int32)                       # low-bits word
    off = pos % 32
    lo = u << off                                            # wraps mod 2^32:
    # the dropped high bits are exactly the straddling part, re-emitted as hi
    sh = jnp.where(off == 0, jnp.uint32(0), jnp.uint32(32) - off)
    hi = jnp.where(off == 0, jnp.uint32(0), u >> sh)
    jj = jnp.arange(width, dtype=jnp.int32)                  # [width] words
    contrib = (jnp.where(jj == j1[:, None], lo[..., None], jnp.uint32(0))
               | jnp.where(jj == j1[:, None] + 1, hi[..., None],
                           jnp.uint32(0)))
    # fields within a word are disjoint, so an integer sum == bitwise OR
    return jnp.sum(contrib, axis=-2)


def _unpack_chunk(words: jax.Array, width: int) -> jax.Array:
    """uint32[..., width] words -> uint32[..., 32] fields (< 2**width)."""
    pos = jnp.arange(PACK_CHUNK, dtype=jnp.uint32) * jnp.uint32(width)
    j1 = (pos // 32).astype(jnp.int32)
    off = pos % 32
    jj = jnp.arange(width, dtype=jnp.int32)
    w1 = jnp.sum(jnp.where(jj == j1[:, None], words[..., None, :],
                           jnp.uint32(0)), axis=-1)
    w2 = jnp.sum(jnp.where(jj == j1[:, None] + 1, words[..., None, :],
                           jnp.uint32(0)), axis=-1)
    sh = jnp.where(off == 0, jnp.uint32(0), jnp.uint32(32) - off)
    u = (w1 >> off) | jnp.where(off == 0, jnp.uint32(0), w2 << sh)
    mask = jnp.uint32(0xFFFFFFFF if width == 32 else (1 << width) - 1)
    return u & mask


def packed_words(count: int, width: int) -> int:
    """uint32 words needed for ``count`` fields of ``width`` bits (host int)."""
    return -(-count * width // 32)


def bitpack_rows_ref(u: jax.Array, width: int) -> jax.Array:
    """Pack uint32[R, k] fields (each < 2**width) into uint32[R, W] words,
    W = ceil(k*width/32); big-endian-in-row, little-endian-in-word layout."""
    R, k = u.shape
    nc = -(-k // PACK_CHUNK)
    up = jnp.pad(u.astype(jnp.uint32), ((0, 0), (0, nc * PACK_CHUNK - k)))
    words = _pack_chunk(up.reshape(R, nc, PACK_CHUNK), width)
    return words.reshape(R, nc * width)[:, :packed_words(k, width)]


def bitunpack_rows_ref(words: jax.Array, k: int, width: int) -> jax.Array:
    """Inverse of :func:`bitpack_rows_ref`: uint32[R, W] -> uint32[R, k]."""
    R = words.shape[0]
    nc = -(-k // PACK_CHUNK)
    wp = jnp.pad(words.astype(jnp.uint32),
                 ((0, 0), (0, nc * width - words.shape[1])))
    u = _unpack_chunk(wp.reshape(R, nc, width), width)
    return u.reshape(R, nc * PACK_CHUNK)[:, :k]


def mask_prng_ref(g, seed: int, *, p: float, q: float, sigma: float,
                  sign: float = 1.0):
    """Counter-based sparse-mask generation + add (paper Eq. 3-5 data plane).

    u(i) = mix32(seed ^ i) mapped to [p, p+q); the mask is kept only where
    u(i) < sigma (expected support fraction (sigma-p)/q) and added to g.
    Returns (masked, mask) — both parties regenerate `mask` identically from
    the shared seed, so +/- copies cancel at the aggregator.
    """
    n = g.size
    idx = jnp.arange(n, dtype=jnp.uint32)
    h = _mix32(idx ^ jnp.uint32(seed))
    u = p + q * (h.astype(jnp.float32) / jnp.float32(2**32))
    mask = jnp.where(u < sigma, u, 0.0).reshape(g.shape) * sign
    return (g.astype(jnp.float32) + mask).astype(g.dtype), mask
