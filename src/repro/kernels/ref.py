"""Pure-jnp oracles for every Pallas kernel (the allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """[B,T,H,hd] x [B,S,Hkv,hd] GQA attention, f32 math."""
    b, t, h, hd = q.shape
    s = k.shape[1]
    group = h // k.shape[2]
    kf = jnp.repeat(k, group, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=2).astype(jnp.float32)
    scores = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32), kf) / (hd ** 0.5)
    q_pos = jnp.arange(t)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, vf)
    return out.astype(q.dtype)


def thgs_sparsify_ref(g, residual, threshold):
    """Fused THGS threshold step: acc = g + residual; split at |acc| > delta."""
    acc = (g.astype(jnp.float32) + residual.astype(jnp.float32))
    keep = jnp.abs(acc) > threshold
    sparse = jnp.where(keep, acc, 0.0)
    new_resid = jnp.where(keep, 0.0, acc)
    return sparse.astype(g.dtype), new_resid.astype(residual.dtype)


def _mix32(x: jax.Array) -> jax.Array:
    """murmur3-style avalanche of uint32 lanes (the kernel uses the same)."""
    x = x.astype(jnp.uint32)
    x ^= x >> 16
    x *= jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x *= jnp.uint32(0x846CA68B)
    x ^= x >> 16
    return x


def stream_scatter_add_ref(indices, values, size: int):
    """Scatter-add a flat stream into dense f32[size]; out-of-range dropped."""
    idx = indices.reshape(-1).astype(jnp.int32)
    val = values.reshape(-1).astype(jnp.float32)
    valid = (idx >= 0) & (idx < size)
    return jnp.zeros((size,), jnp.float32).at[
        jnp.where(valid, idx, 0)].add(jnp.where(valid, val, 0.0))


def mask_prng_ref(g, seed: int, *, p: float, q: float, sigma: float,
                  sign: float = 1.0):
    """Counter-based sparse-mask generation + add (paper Eq. 3-5 data plane).

    u(i) = mix32(seed ^ i) mapped to [p, p+q); the mask is kept only where
    u(i) < sigma (expected support fraction (sigma-p)/q) and added to g.
    Returns (masked, mask) — both parties regenerate `mask` identically from
    the shared seed, so +/- copies cancel at the aggregator.
    """
    n = g.size
    idx = jnp.arange(n, dtype=jnp.uint32)
    h = _mix32(idx ^ jnp.uint32(seed))
    u = p + q * (h.astype(jnp.float32) / jnp.float32(2**32))
    mask = jnp.where(u < sigma, u, 0.0).reshape(g.shape) * sign
    return (g.astype(jnp.float32) + mask).astype(g.dtype), mask
