"""Pallas TPU flash attention (causal GQA, optional sliding window).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv axis is minor-most,
so the VMEM scratch (running max m, normalizer l, accumulator acc) persists
across kv steps of one (b, h, q_block) tile; the output tile is written on the
last kv step. Block shapes keep the working set in VMEM:
  q tile  [block_q, hd]   k/v tiles [block_kv, hd]   acc [block_q, hd] f32
with MXU-aligned block_q/block_kv (multiples of 128) and f32 accumulation.

GQA: the kv BlockSpec index_map folds the query head onto its kv head
(h // group_size), so no repeated K/V ever materializes.

Causality/window: kv blocks entirely in the future are skipped by masking;
fully-masked tiles still execute (TPU grids are dense) but contribute zero —
the ops.py wrapper additionally shrinks the kv grid to the causal hull.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, block_q: int, block_kv: int,
                  causal: bool, window: Optional[int], kv_len: int):
    qb = pl.program_id(2)
    kvb = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(kvb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)          # [block_q, hd]
    k = k_ref[...].astype(jnp.float32)          # [block_kv, hd]
    v = v_ref[...].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 0)
    k_pos = kvb * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_kv), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                          # [block_q, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                       # [block_q, block_kv]
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kvb == n_kv - 1)
    def _emit():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,           # [B, T, H, hd]
    k: jax.Array,           # [B, S, Hkv, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, t, h, hd = q.shape
    s = k.shape[1]
    h_kv = k.shape[2]
    assert h % h_kv == 0
    group = h // h_kv
    assert t % block_q == 0 and s % block_kv == 0
    sm_scale = hd ** -0.5

    grid = (b, h, t // block_q, s // block_kv)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, block_q=block_q, block_kv=block_kv,
        causal=causal, window=window, kv_len=s)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, None, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((None, block_kv, None, hd),
                         lambda bi, hi, qi, ki, g=group: (bi, ki, hi // g, 0)),
            pl.BlockSpec((None, block_kv, None, hd),
                         lambda bi, hi, qi, ki, g=group: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, hd),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _scratch((block_q, 1), jnp.float32),
            _scratch((block_q, 1), jnp.float32),
            _scratch((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _scratch(shape, dtype):
    from jax.experimental import pallas as pl  # local: keep module import light

    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover — interpret-only environments
        return pl.MemorySpace.ANY(shape, dtype)  # type: ignore
