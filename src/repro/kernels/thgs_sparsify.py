"""Pallas TPU kernel: fused THGS threshold split (the sparsifier's hot loop).

One HBM pass instead of four: reads (g, residual), writes (sparse, new_residual)
tile by tile — acc = g + residual; sparse = acc·1[|acc|>δ]; residual' = acc−sparse.
This is the memory-bound inner step of Alg. 1 once the per-layer threshold δ is
known (δ itself comes from top-k / sampled selection in core/sparsify.py).

Block layout: inputs flattened to [rows, 128-lane] tiles; block_rows chosen so
4 tiles (2 in + 2 out) fit comfortably in VMEM.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _kernel(g_ref, r_ref, thr_ref, s_ref, out_r_ref):
    acc = g_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    keep = jnp.abs(acc) > thr_ref[0, 0]
    sparse = jnp.where(keep, acc, 0.0)
    s_ref[...] = sparse.astype(s_ref.dtype)
    out_r_ref[...] = (acc - sparse).astype(out_r_ref.dtype)


def thgs_sparsify(g: jax.Array, residual: jax.Array, threshold: jax.Array,
                  *, block_rows: int = 256, interpret: bool = False):
    """g, residual: same shape/any rank; threshold: scalar. Returns (sparse, resid)."""
    orig_shape, orig_dtype = g.shape, g.dtype
    n = g.size
    rows = -(-n // LANE)
    pad = rows * LANE - n
    gf = jnp.pad(g.reshape(-1), (0, pad)).reshape(rows, LANE)
    rf = jnp.pad(residual.reshape(-1), (0, pad)).reshape(rows, LANE)
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)
    thr = jnp.asarray(threshold, jnp.float32).reshape(1, 1)

    sparse, resid = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), orig_dtype),
            jax.ShapeDtypeStruct((rows, LANE), residual.dtype),
        ],
        interpret=interpret,
    )(gf, rf, thr)
    unpad = lambda x: x.reshape(-1)[:n].reshape(orig_shape)
    return unpad(sparse), unpad(resid).astype(residual.dtype)
