"""Pallas TPU kernel: fused sparse-stream scatter-add (the server decode).

The secure-aggregation server's hot loop (DESIGN.md §3): all clients' unified
streams — one flat (indices, values) vector after weighting/liveness gating —
scatter-added into the dense update buffer in ONE pass over HBM. The seed
implementation re-read and re-wrote the dense buffer once per client; this
kernel writes every dense tile exactly once while the (small) stream chunks
cycle through VMEM.

Scatter on TPU is formulated MXU-style: for a dense tile [TR, LANE] and a
stream chunk of KC entries, build the row one-hot [TR, KC] and lane one-hot
[KC, LANE] and contract — ``tile += rowhot @ (vals * lanehot)``. Duplicate
indices accumulate through the contraction, matching scatter-add semantics.

Grid = (dense tiles, stream chunks); the output tile's index map ignores the
chunk axis, so the tile stays resident in VMEM and accumulates across the
inner grid dimension (the standard Pallas reduction pattern). Entries with
index outside [0, size) — e.g. the -1 padding the wrapper adds — are dropped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _kernel(idx_ref, val_ref, o_ref, *, tile_rows: int):
    i = pl.program_id(0)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]                       # int32[1, KC]
    val = val_ref[...]                       # f32 [1, KC]
    kc = idx.shape[1]
    base = i * tile_rows * LANE
    rel = idx - base
    inrange = (rel >= 0) & (rel < tile_rows * LANE)
    rel_c = jnp.where(inrange, rel, 0)
    row = rel_c // LANE                      # [1, KC]
    lane = rel_c % LANE                      # [1, KC]

    row_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_rows, kc), 0)
    rowhot = ((row_iota == row) & inrange).astype(jnp.float32)   # [TR, KC]
    lane_iota = jax.lax.broadcasted_iota(jnp.int32, (kc, LANE), 1)
    lanehot = (lane_iota == lane.reshape(kc, 1)).astype(jnp.float32)
    weighted = val.reshape(kc, 1) * lanehot                       # [KC, LANE]
    o_ref[...] += jax.lax.dot(rowhot, weighted,
                              preferred_element_type=jnp.float32)


def stream_scatter_add(
    indices: jax.Array,        # int32[n] flat indices; out-of-range dropped
    values: jax.Array,         # [n] accumulated as f32
    size: int,
    *,
    tile_rows: int = 64,
    chunk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """One-HBM-pass scatter-add of a flat stream into a dense f32[size]."""
    n = indices.shape[0]
    rows = -(-size // LANE)
    n_tiles = -(-rows // tile_rows)
    pad_n = -(-max(n, 1) // chunk) * chunk - n
    idx = jnp.pad(indices.reshape(-1).astype(jnp.int32), (0, pad_n),
                  constant_values=-1)
    val = jnp.pad(values.reshape(-1).astype(jnp.float32), (0, pad_n))
    n_chunks = idx.shape[0] // chunk
    idx2 = idx.reshape(n_chunks, chunk)
    val2 = val.reshape(n_chunks, chunk)

    dense = pl.pallas_call(
        functools.partial(_kernel, tile_rows=tile_rows),
        grid=(n_tiles, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i, j: (j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows, LANE), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * tile_rows, LANE),
                                       jnp.float32),
        interpret=interpret,
    )(idx2, val2)
    return dense.reshape(-1)[:size]
