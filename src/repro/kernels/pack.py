"""Pallas TPU kernels: fixed-width bit packing for the stream wire format.

The StreamCodec stage (core/codecs.py, DESIGN.md §12) ships quantized stream
values and delta-encoded sparse indices as dense fields of ``width`` bits
packed into uint32 words. Rows are processed in 32-slot chunks: a chunk at
field width ``w`` occupies exactly ``32*w`` bits = ``w`` whole words, so
chunks never straddle word boundaries and the kernel grids over
(row tiles, chunk groups) with statically-windowed input AND output blocks —
no cross-step accumulation. The kernel body is ref.py's ``_pack_chunk`` /
``_unpack_chunk`` verbatim, which is what makes kernel/ref parity bit-exact
by construction (pinned in tests/test_kernels.py over odd sizes and padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import PACK_CHUNK, _pack_chunk, _unpack_chunk, \
    packed_words

LANE = 128
CHUNKS_PER_TILE = LANE // PACK_CHUNK   # 4 chunks = one 128-slot lane row
ROW_TILE = 8


def _pack_kernel(u_ref, o_ref, *, width: int):
    tr, st = u_ref.shape
    u = u_ref[...].astype(jnp.uint32).reshape(tr, st // PACK_CHUNK,
                                              PACK_CHUNK)
    o_ref[...] = _pack_chunk(u, width).reshape(tr, -1)


def _unpack_kernel(w_ref, o_ref, *, width: int):
    tr, ww = w_ref.shape
    words = w_ref[...].astype(jnp.uint32).reshape(tr, ww // width, width)
    o_ref[...] = _unpack_chunk(words, width).reshape(tr, -1)


def bitpack_rows(u: jax.Array, width: int, *, row_tile: int = ROW_TILE,
                 interpret: bool = False) -> jax.Array:
    """Pack uint32[R, k] fields (each < ``2**width``) into uint32[R, W] words,
    ``W = ceil(k*width/32)``. Padding slots are zero bits; padded rows/words
    are sliced off before returning."""
    R, k = u.shape
    W = packed_words(k, width)
    nc = -(-k // LANE) * CHUNKS_PER_TILE          # chunks, multiple of 4
    rows = -(-R // row_tile) * row_tile
    up = jnp.pad(u.astype(jnp.uint32),
                 ((0, rows - R), (0, nc * PACK_CHUNK - k)))
    words = pl.pallas_call(
        functools.partial(_pack_kernel, width=width),
        grid=(rows // row_tile, nc // CHUNKS_PER_TILE),
        in_specs=[pl.BlockSpec((row_tile, LANE), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((row_tile, CHUNKS_PER_TILE * width),
                               lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, nc * width), jnp.uint32),
        interpret=interpret,
    )(up)
    return words[:R, :W]


def bitunpack_rows(words: jax.Array, k: int, width: int, *,
                   row_tile: int = ROW_TILE,
                   interpret: bool = False) -> jax.Array:
    """Inverse of :func:`bitpack_rows`: uint32[R, W] words -> uint32[R, k]
    fields, each < ``2**width``."""
    R = words.shape[0]
    nc = -(-k // LANE) * CHUNKS_PER_TILE
    rows = -(-R // row_tile) * row_tile
    wp = jnp.pad(words.astype(jnp.uint32),
                 ((0, rows - R), (0, nc * width - words.shape[1])))
    u = pl.pallas_call(
        functools.partial(_unpack_kernel, width=width),
        grid=(rows // row_tile, nc // CHUNKS_PER_TILE),
        in_specs=[pl.BlockSpec((row_tile, CHUNKS_PER_TILE * width),
                               lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((row_tile, LANE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, nc * PACK_CHUNK), jnp.uint32),
        interpret=interpret,
    )(wp)
    return u[:R, :k]
