"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel body
runs as traced jnp ops, which is how correctness is validated against ref.py.
On TPU they compile to Mosaic with the BlockSpec tilings declared in each file.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mask_prng import mask_prng_apply as _mask
from repro.kernels.mask_prng import pair_mask_streams as _pair_streams
from repro.kernels.pack import bitpack_rows as _bitpack
from repro.kernels.pack import bitunpack_rows as _bitunpack
from repro.kernels.stream_decode import stream_scatter_add as _scatter
from repro.kernels.thgs_sparsify import thgs_sparsify as _thgs


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_kv: int = 128):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_kv=block_kv, interpret=_interpret())


@jax.jit
def thgs_sparsify(g, residual, threshold):
    return _thgs(g, residual, threshold, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("seed", "p", "q", "sigma", "sign"))
def mask_prng_apply(g, *, seed: int, p: float = -1.0, q: float = 2.0,
                    sigma: float, sign: float = 1.0):
    return _mask(g, seed, p=p, q=q, sigma=sigma, sign=sign,
                 interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("size", "tile_rows", "chunk"))
def stream_scatter_add(indices, values, *, size: int, tile_rows: int = 64,
                       chunk: int = 512):
    """Fused server decode: flat stream -> dense f32[size] in one HBM pass."""
    return _scatter(indices, values, size, tile_rows=tile_rows, chunk=chunk,
                    interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("nb", "k_mask", "m", "p", "q"))
def pair_mask_streams(seeds, signs, *, nb: int, k_mask: int, m: int,
                      p: float = -1.0, q: float = 2.0):
    """All of a round's pair-mask streams in one fused pass (Eq. 3-4).

    uint32 seeds + f32 signs, one per active pair -> counter-based
    ``(idx, vals)`` support streams. Pallas kernel on TPU; the bit-identical
    jnp oracle elsewhere (the ref IS the fallback — it vmaps/traces freely
    inside the batched encode, interpret-mode kernel parity is pinned in
    tests/test_kernels.py).
    """
    if _interpret():
        return ref.pair_mask_stream_ref(seeds, signs, nb, k_mask, m, p=p, q=q)
    return _pair_streams(seeds, signs, nb=nb, k_mask=k_mask, m=m, p=p, q=q)


@functools.partial(jax.jit, static_argnames=("width",))
def bitpack_rows(u, *, width: int):
    """Pack uint32[R, k] fields of ``width`` bits into uint32 words — the
    StreamCodec wire data plane (core/codecs.py, DESIGN.md §12). Pallas
    kernel on TPU; the chunk-identical jnp oracle elsewhere (the ref IS the
    fallback — interpret-mode kernel parity is pinned in
    tests/test_kernels.py)."""
    if _interpret():
        return ref.bitpack_rows_ref(u, width)
    return _bitpack(u, width)


@functools.partial(jax.jit, static_argnames=("k", "width"))
def bitunpack_rows(words, *, k: int, width: int):
    """Inverse of :func:`bitpack_rows`: words -> uint32[R, k] fields."""
    if _interpret():
        return ref.bitunpack_rows_ref(words, k, width)
    return _bitunpack(words, k, width)
