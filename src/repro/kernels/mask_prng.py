"""Pallas TPU kernel: counter-based sparse-mask generation + apply (Eq. 3-5).

Secure aggregation's data-plane hot loop: for each parameter position i, derive
a uniform u(i) in [p, p+q) from a murmur-style 32-bit avalanche of (seed ^ i)
(counter-based — masks are *recomputed*, never stored, so the mask matrix costs
zero HBM), keep it only where u(i) < sigma (Eq. 4's threshold: expected support
fraction (sigma-p)/q = k/x), and add it to the gradient tile in one pass.

Both endpoints of a pair run the same kernel with the same seed and opposite
``sign``, so the aggregated masks cancel exactly. Matches ref.mask_prng_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import IDX_SALT, VAL_SALT, _mix32

LANE = 128


def _kernel(g_ref, o_ref, m_ref, *, seed: int, p: float, q: float,
            sigma: float, sign: float, block_rows: int):
    i = pl.program_id(0)
    base = i * block_rows * LANE
    idx = base + jax.lax.broadcasted_iota(jnp.int32, g_ref.shape, 0) * LANE \
        + jax.lax.broadcasted_iota(jnp.int32, g_ref.shape, 1)
    x = _mix32(idx.astype(jnp.uint32) ^ jnp.uint32(seed))
    u = p + q * (x.astype(jnp.float32) / jnp.float32(2**32))
    mask = jnp.where(u < sigma, u, 0.0) * sign
    m_ref[...] = mask
    o_ref[...] = (g_ref[...].astype(jnp.float32) + mask).astype(o_ref.dtype)


def mask_prng_apply(g: jax.Array, seed: int, *, p: float = -1.0, q: float = 2.0,
                    sigma: float, sign: float = 1.0, block_rows: int = 256,
                    interpret: bool = False):
    """Returns (g + mask, mask) with the sparse pairwise mask regenerated on the
    fly. g: any shape."""
    orig_shape = g.shape
    n = g.size
    rows = -(-n // LANE)
    pad = rows * LANE - n
    gf = jnp.pad(g.reshape(-1), (0, pad)).reshape(rows, LANE)
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)

    kernel = functools.partial(_kernel, seed=seed, p=p, q=q, sigma=sigma,
                               sign=sign, block_rows=block_rows)
    out, mask = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), g.dtype),
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(gf)
    unpad = lambda x: x.reshape(-1)[:n].reshape(orig_shape)
    return unpad(out), unpad(mask)


def _pair_stream_kernel(s_ref, sg_ref, i_ref, v_ref, *, L: int, m: int,
                        p: float, q: float, rows: int):
    """One grid step = one pair: counter-based (idx, val) slots for that pair.

    The per-pair seed arrives as a (1, 1)-blocked 2-D operand — rank >= 2 is
    what Mosaic accepts for VMEM inputs (rank-1 blocks only lower in
    interpret mode); a scalar-prefetch SMEM ride would also work but the
    plain 2-D BlockSpec keeps the interpret and TPU paths identical.
    Counters past ``L`` are padding lanes; they are zeroed and sliced off by
    the wrapper.
    """
    seed = s_ref[0, 0]
    sign = sg_ref[0, 0]
    c = (jax.lax.broadcasted_iota(jnp.uint32, (rows, LANE), 0) * LANE
         + jax.lax.broadcasted_iota(jnp.uint32, (rows, LANE), 1))
    base_i = _mix32(seed ^ jnp.uint32(IDX_SALT))
    base_v = _mix32(seed ^ jnp.uint32(VAL_SALT))
    idx = (_mix32(base_i + c) % jnp.uint32(m)).astype(jnp.int32)
    # top 24 bits: the f32-exact uniform grid (see ref.pair_mask_stream_ref)
    u = (_mix32(base_v + c) >> 8).astype(jnp.float32) / jnp.float32(2**24)
    val = sign * (p + q * u)
    valid = c < jnp.uint32(L)
    i_ref[...] = jnp.where(valid, idx, 0)[None]
    v_ref[...] = jnp.where(valid, val, 0.0)[None]


def pair_mask_streams(seeds: jax.Array, signs: jax.Array, *, nb: int,
                      k_mask: int, m: int, p: float = -1.0, q: float = 2.0,
                      interpret: bool = False):
    """All pair masks of a round in ONE fused pass (paper Eq. 3-4 data plane).

    ``seeds`` uint32[N] (one per active pair, leaf already folded in) and
    ``signs`` f32[N] produce ``(idx int32[N, nb, k_mask], vals f32)`` —
    the sparse-support counterpart of :func:`mask_prng_apply`'s dense sigma
    thresholding, matching ``ref.pair_mask_stream_ref`` bit for bit. Grid is
    one step per pair; each step fills that pair's ``nb * k_mask`` slots from
    a murmur-avalanched counter stream, so masks are regenerated on the fly
    (zero HBM for the mask matrix) exactly as the dense kernel does.
    """
    n_pairs = seeds.shape[0]
    L = nb * k_mask
    rows = max(1, -(-L // LANE))
    kernel = functools.partial(_pair_stream_kernel, L=L, m=m, p=p, q=q,
                               rows=rows)
    idx, vals = pl.pallas_call(
        kernel,
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, rows, LANE), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, rows, LANE), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pairs, rows, LANE), jnp.int32),
            jax.ShapeDtypeStruct((n_pairs, rows, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(seeds.astype(jnp.uint32).reshape(n_pairs, 1),
      signs.astype(jnp.float32).reshape(n_pairs, 1))
    idx = idx.reshape(n_pairs, rows * LANE)[:, :L].reshape(n_pairs, nb, k_mask)
    vals = vals.reshape(n_pairs, rows * LANE)[:, :L].reshape(
        n_pairs, nb, k_mask)
    return idx, vals
