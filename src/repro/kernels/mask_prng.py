"""Pallas TPU kernel: counter-based sparse-mask generation + apply (Eq. 3-5).

Secure aggregation's data-plane hot loop: for each parameter position i, derive
a uniform u(i) in [p, p+q) from a murmur-style 32-bit avalanche of (seed ^ i)
(counter-based — masks are *recomputed*, never stored, so the mask matrix costs
zero HBM), keep it only where u(i) < sigma (Eq. 4's threshold: expected support
fraction (sigma-p)/q = k/x), and add it to the gradient tile in one pass.

Both endpoints of a pair run the same kernel with the same seed and opposite
``sign``, so the aggregated masks cancel exactly. Matches ref.mask_prng_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _kernel(g_ref, o_ref, m_ref, *, seed: int, p: float, q: float,
            sigma: float, sign: float, block_rows: int):
    i = pl.program_id(0)
    base = i * block_rows * LANE
    idx = base + jax.lax.broadcasted_iota(jnp.int32, g_ref.shape, 0) * LANE \
        + jax.lax.broadcasted_iota(jnp.int32, g_ref.shape, 1)
    x = idx.astype(jnp.uint32) ^ jnp.uint32(seed)
    x ^= x >> 16
    x *= jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x *= jnp.uint32(0x846CA68B)
    x ^= x >> 16
    u = p + q * (x.astype(jnp.float32) / jnp.float32(2**32))
    mask = jnp.where(u < sigma, u, 0.0) * sign
    m_ref[...] = mask
    o_ref[...] = (g_ref[...].astype(jnp.float32) + mask).astype(o_ref.dtype)


def mask_prng_apply(g: jax.Array, seed: int, *, p: float = -1.0, q: float = 2.0,
                    sigma: float, sign: float = 1.0, block_rows: int = 256,
                    interpret: bool = False):
    """Returns (g + mask, mask) with the sparse pairwise mask regenerated on the
    fly. g: any shape."""
    orig_shape = g.shape
    n = g.size
    rows = -(-n // LANE)
    pad = rows * LANE - n
    gf = jnp.pad(g.reshape(-1), (0, pad)).reshape(rows, LANE)
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)

    kernel = functools.partial(_kernel, seed=seed, p=p, q=q, sigma=sigma,
                               sign=sign, block_rows=block_rows)
    out, mask = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), g.dtype),
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(gf)
    unpad = lambda x: x.reshape(-1)[:n].reshape(orig_shape)
    return unpad(out), unpad(mask)
