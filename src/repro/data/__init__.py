from repro.data.datasets import (CIFAR10, FASHION_MNIST, MNIST, SPECS,
                                 DatasetSpec, make_dataset, make_lm_tokens)
from repro.data.federated import (client_batches, dirichlet, iid,
                                  noniid_label_k)

__all__ = ["CIFAR10", "FASHION_MNIST", "MNIST", "SPECS", "DatasetSpec",
           "make_dataset", "make_lm_tokens", "client_batches", "dirichlet",
           "iid", "noniid_label_k"]
