"""Synthetic datasets with the shapes/classes of the paper's benchmarks.

The container is offline, so MNIST / Fashion-MNIST / CIFAR-10 are replaced by a
deterministic class-prototype generative model: each class c has a fixed random
prototype image; a sample is prototype + structured low-rank distortion + noise.
Learnable (a linear probe separates classes), non-trivial (prototypes overlap),
and fully reproducible — see DESIGN.md §7 dataset note.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    shape: tuple           # per-sample shape
    n_classes: int
    n_train: int
    n_test: int


MNIST = DatasetSpec("mnist", (28, 28, 1), 10, 60_000, 10_000)
FASHION_MNIST = DatasetSpec("fashion_mnist", (28, 28, 1), 10, 60_000, 10_000)
CIFAR10 = DatasetSpec("cifar10", (32, 32, 3), 10, 50_000, 10_000)

SPECS = {s.name: s for s in (MNIST, FASHION_MNIST, CIFAR10)}


def make_dataset(spec: DatasetSpec, n: int | None = None, *, seed: int = 0,
                 noise: float = 0.35, train: bool = True):
    """Returns (x: float32[n, *shape], y: int32[n])."""
    n = n if n is not None else (spec.n_train if train else spec.n_test)
    # stable digest, NOT builtin hash(): str hashing is salted per process
    # (PYTHONHASHSEED), which made the class prototypes — and so every
    # accuracy — differ between otherwise identical runs
    digest = zlib.crc32(f"{spec.name}/17".encode())
    rng = np.random.RandomState(digest % (2**31))
    protos = rng.randn(spec.n_classes, *spec.shape).astype(np.float32)
    # low-rank distortion directions per class
    dirs = rng.randn(spec.n_classes, 4, *spec.shape).astype(np.float32) * 0.5

    rs = np.random.RandomState(seed + (0 if train else 10_000))
    y = rs.randint(0, spec.n_classes, size=n).astype(np.int32)
    coef = rs.randn(n, 4).astype(np.float32)
    x = protos[y]
    x = x + np.einsum("nk,nk...->n...", coef, dirs[y])
    x = x + noise * rs.randn(*x.shape).astype(np.float32)
    return x.astype(np.float32), y


def make_lm_tokens(vocab: int, n_seqs: int, seq_len: int, *, seed: int = 0):
    """Synthetic token streams with local structure (order-2 Markov-ish) so an LM
    can reduce loss below uniform; labels are next-token shifted."""
    rs = np.random.RandomState(seed)
    # block-structured transition: token t+1 ~ (a*t + b) mod vocab with noise
    a = rs.randint(1, 7, size=n_seqs)
    b = rs.randint(0, vocab, size=n_seqs)
    t0 = rs.randint(0, vocab, size=n_seqs)
    toks = np.zeros((n_seqs, seq_len + 1), np.int32)
    toks[:, 0] = t0
    for i in range(seq_len):
        nxt = (a * toks[:, i] + b) % vocab
        flip = rs.rand(n_seqs) < 0.15
        nxt = np.where(flip, rs.randint(0, vocab, size=n_seqs), nxt)
        toks[:, i + 1] = nxt
    return toks[:, :-1], toks[:, 1:]
