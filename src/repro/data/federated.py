"""Federated data partitioning (paper §5 experimental protocol).

Two non-IID schemes:
  * ``noniid_label_k`` — the paper's Non-IID-n: each client holds samples from
    exactly n of the 10 label classes (sample-allocation-matrix construction).
  * ``dirichlet`` — the standard Dir(alpha) partition for sensitivity studies.
Plus ``iid`` uniform shuffling. All return {client_id: index array}.
"""
from __future__ import annotations

import numpy as np


def iid(y: np.ndarray, n_clients: int, *, seed: int = 0) -> dict[int, np.ndarray]:
    rs = np.random.RandomState(seed)
    idx = rs.permutation(len(y))
    return {c: np.sort(part) for c, part in
            enumerate(np.array_split(idx, n_clients))}


def noniid_label_k(y: np.ndarray, n_clients: int, k: int, *,
                   seed: int = 0) -> dict[int, np.ndarray]:
    """Paper's Non-IID-k: every client sees exactly k distinct labels.

    Each class's samples are split into shards; each client draws shards from k
    classes assigned round-robin so all classes stay covered.
    """
    rs = np.random.RandomState(seed)
    classes = np.unique(y)
    n_classes = len(classes)
    assert 1 <= k <= n_classes
    # class list per client, round-robin offset so coverage is balanced
    client_classes = [
        [classes[(c + j) % n_classes] for j in range(k)] for c in range(n_clients)
    ]
    # shard each class among the clients that want it
    want = {cls: [c for c in range(n_clients) if cls in client_classes[c]]
            for cls in classes}
    out = {c: [] for c in range(n_clients)}
    for cls in classes:
        idx = np.where(y == cls)[0]
        rs.shuffle(idx)
        takers = want[cls]
        if not takers:
            continue
        for taker, part in zip(takers, np.array_split(idx, len(takers))):
            out[taker].append(part)
    return {c: np.sort(np.concatenate(parts)) if parts else np.array([], int)
            for c, parts in out.items()}


def dirichlet(y: np.ndarray, n_clients: int, alpha: float = 0.5, *,
              seed: int = 0) -> dict[int, np.ndarray]:
    rs = np.random.RandomState(seed)
    out = {c: [] for c in range(n_clients)}
    for cls in np.unique(y):
        idx = np.where(y == cls)[0]
        rs.shuffle(idx)
        props = rs.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for c, part in enumerate(np.split(idx, cuts)):
            out[c].append(part)
    return {c: np.sort(np.concatenate(parts)) for c, parts in out.items()}


def client_batches(x: np.ndarray, y: np.ndarray, idx: np.ndarray,
                   batch: int, steps: int, *, seed: int = 0):
    """Stacked [steps, batch, ...] arrays for one client's local round."""
    rs = np.random.RandomState(seed)
    take = rs.choice(idx, size=steps * batch, replace=len(idx) < steps * batch)
    xb = x[take].reshape(steps, batch, *x.shape[1:])
    yb = y[take].reshape(steps, batch)
    return xb, yb
