"""Shared layer primitives: norms, RoPE variants, MLPs, initializers.

Models are pure functions over parameter pytrees (no flax): ``init_*`` builds the
params, the forward functions consume them. Everything is jit/pjit-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- initializers
def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (scale * jax.random.normal(key, (d_in, d_out))).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ----------------------------------------------------------------------- norms
def init_norm(d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, -1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# ------------------------------------------------------------------------ RoPE
def rope_freqs(hd: int, positions: jax.Array, theta: float = 10000.0):
    """positions: int32[...]; returns (cos, sin) of shape positions.shape + (hd//2,)."""
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, mode: str = "default") -> jax.Array:
    """x: [..., T, H, hd]; positions: [..., T] (broadcastable).

    mode='default': rotate the full head dim (llama style, interleaved-pairs-free
    "split-half" convention). mode='2d': ChatGLM convention — rotary on the first
    half of the head dim only, second half passes through. mode='none': identity.
    """
    if mode == "none":
        return x
    hd = x.shape[-1]
    rot_d = hd if mode == "default" else hd // 2
    rot_d = rot_d - (rot_d % 2)
    xr, xp = x[..., :rot_d], x[..., rot_d:]
    cos, sin = rope_freqs(rot_d, positions)          # [..., T, rot_d/2]
    cos = cos[..., None, :].astype(x.dtype)          # broadcast over heads
    sin = sin[..., None, :].astype(x.dtype)
    x1, x2 = xr[..., : rot_d // 2], xr[..., rot_d // 2:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([rotated, xp], -1)


# ------------------------------------------------------------------------ MLPs
def init_mlp(key, d: int, d_ff: int, act: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wo": dense_init(ks[2], d_ff, d, dtype)}
    if act == "swiglu":
        p["wi_gate"] = dense_init(ks[0], d, d_ff, dtype)
        p["wi_up"] = dense_init(ks[1], d, d_ff, dtype)
    else:
        p["wi"] = dense_init(ks[0], d, d_ff, dtype)
    return p


def apply_mlp(p: dict, x: jax.Array, act: str) -> jax.Array:
    from repro.models.sharding import shard

    if act == "swiglu":
        h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    if h.ndim == 3:  # keep d_ff tensor-parallel through the activation
        h = shard(h, "batch", None, "model")
    return h @ p["wo"]
