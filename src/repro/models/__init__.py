from repro.models import (attention, layers, moe, paper_models, sharding,
                          ssm, transformer, xlstm)

__all__ = ["attention", "layers", "moe", "paper_models", "sharding", "ssm",
           "transformer", "xlstm"]
