"""GQA attention: full/causal/sliding-window/cross, prefill + single-token decode.

GQA is computed NATIVELY (queries reshaped to [B,T,kv,group,hd] and contracted
against the un-repeated K/V): materializing repeated K/V via jnp.repeat forces
GSPMD to all-gather a sequence-sharded KV cache (measured: a 1 GiB full-cache
gather per layer on long_500k decode — §Perf iteration 1).

Decode attends over a pre-allocated KV cache of length ``cache_len`` with a
validity mask; the cache layout [B, S, kv, hd] shards S over the 'model' mesh
axis (flash-decode: the softmax reduction over the sharded S axis lowers to
small partial-reduce collectives).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init
from repro.models.sharding import shard

NEG_INF = -1e30
KV_QSCALE = 0.05  # int8 KV quantization step (beyond-paper decode option)


class KVCache(NamedTuple):
    k: jax.Array        # [B, S, kv, hd]
    v: jax.Array        # [B, S, kv, hd]
    length: jax.Array   # int32[B] valid prefix length


def init_attention(key, d: int, n_heads: int, n_kv: int, hd: int, dtype,
                   cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, n_kv * hd, dtype),
        "wv": dense_init(ks[2], d, n_kv * hd, dtype),
        "wo": dense_init(ks[3], n_heads * hd, d, dtype),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, hd)


def _q_groups(q: jax.Array, n_kv: int) -> jax.Array:
    """[B,T,H,hd] -> [B,T,kv,g,hd] — GQA grouping without repeating K/V."""
    b, t, h, hd = q.shape
    return q.reshape(b, t, n_kv, h // n_kv, hd)


def attend(q: jax.Array, k: jax.Array, v: jax.Array, mask: Optional[jax.Array],
           hd: int) -> jax.Array:
    """GQA attention. q: [B,T,H,hd]; k,v: [B,S,kv,hd] (kv divides H);
    mask broadcastable to [B,1,1,T,S]. Returns [B,T,H,hd]."""
    b, t, h, _ = q.shape
    n_kv = k.shape[2]
    qg = _q_groups(q, n_kv)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k) / (hd ** 0.5)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(b, t, h, hd)


def causal_mask(t: int, s: int, window: Optional[int] = None) -> jax.Array:
    """[t, s] lower-triangular (optionally banded) mask; s >= t aligned at the end."""
    qi = jnp.arange(t)[:, None] + (s - t)
    ki = jnp.arange(s)[None, :]
    m = ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m


# Query-chunk size above which scores are never materialized in full. This is
# the XLA stand-in for the Pallas flash kernel (kernels/flash_attention.py):
# the [T, S] score matrix only ever exists one query-chunk at a time.
CHUNK_Q = 1024


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *, hd: int,
                   causal: bool, window: Optional[int]) -> jax.Array:
    """Memory-bounded GQA attention: lax.map over query chunks of CHUNK_Q."""
    b, t, h, _ = q.shape
    s = k.shape[1]
    if t <= CHUNK_Q:
        mask = (causal_mask(t, s, window)[None, None, None]
                if causal else None)
        return attend(q, k, v, mask, hd)
    assert t % CHUNK_Q == 0, f"T={t} must divide by CHUNK_Q={CHUNK_Q}"
    nc = t // CHUNK_Q
    qc = q.reshape(b, nc, CHUNK_Q, h, hd).swapaxes(0, 1)  # [nc,B,cq,H,hd]

    def one(args):
        qi, start = args
        if causal:
            q_pos = start + jnp.arange(CHUNK_Q)[:, None]
            k_pos = jnp.arange(s)[None, :]
            m = k_pos <= q_pos
            if window is not None:
                m &= k_pos > q_pos - window
            m = m[None, None, None]
        else:
            m = None
        return attend(qi, k, v, m, hd)

    # remat per chunk: backward recomputes scores/probs instead of saving
    # every chunk's [cq, S] tile — flash-attention memory semantics.
    one = jax.checkpoint(one, prevent_cse=False)
    starts = jnp.arange(nc) * CHUNK_Q
    out = jax.lax.map(one, (qc, starts))                  # [nc,B,cq,H,hd]
    return out.swapaxes(0, 1).reshape(b, t, h, hd)


def self_attention(
    p: dict, x: jax.Array, *, n_heads: int, n_kv: int, hd: int,
    rope: str = "default", causal: bool = True, window: Optional[int] = None,
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence self attention (training / prefill)."""
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.arange(t)[None, :]
    q = _split_heads(x @ p["wq"], n_heads, hd)
    k = _split_heads(x @ p["wk"], n_kv, hd)
    v = _split_heads(x @ p["wv"], n_kv, hd)
    q = apply_rope(q, positions, rope)
    k = apply_rope(k, positions, rope)
    # heads over 'model' so per-chunk score tiles stay device-local
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    out = attend_chunked(q, k, v, hd=hd, causal=causal, window=window)
    out = shard(out, "batch", None, "heads", None)
    return out.reshape(b, t, n_heads * hd) @ p["wo"]


def cross_attention(p: dict, x: jax.Array, kv_src: jax.Array, *,
                    n_heads: int, n_kv: int, hd: int) -> jax.Array:
    """x attends to kv_src (e.g. image embeddings); no positional rotation."""
    b, t, _ = x.shape
    q = _split_heads(x @ p["wq"], n_heads, hd)
    k = _split_heads(kv_src @ p["wk"], n_kv, hd)
    v = _split_heads(kv_src @ p["wv"], n_kv, hd)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    out = attend_chunked(q, k, v, hd=hd, causal=False, window=None)
    out = shard(out, "batch", None, "heads", None)
    return out.reshape(b, t, n_heads * hd) @ p["wo"]


def decode_self_attention(
    p: dict, x: jax.Array, cache: KVCache, *, n_heads: int, n_kv: int, hd: int,
    rope: str = "default", window: Optional[int] = None,
) -> tuple[jax.Array, KVCache]:
    """One-token decode: x [B, 1, d]; writes position cache.length into the cache.

    The new K/V are merged at each row's current length via a one-hot masked
    add (elementwise => partitions cleanly when S is sharded); attention runs
    over the full static cache with a validity (+ window) mask, so shapes stay
    static regardless of fill level.
    """
    b, t, _ = x.shape
    assert t == 1, "decode step consumes exactly one new token"
    pos = cache.length[:, None]  # [B,1]
    q = _split_heads(x @ p["wq"], n_heads, hd)
    k_new = _split_heads(x @ p["wk"], n_kv, hd)
    v_new = _split_heads(x @ p["wv"], n_kv, hd)
    q = apply_rope(q, pos, rope)
    k_new = apply_rope(k_new, pos, rope)

    s = cache.k.shape[1]
    quant = cache.k.dtype == jnp.int8
    if quant:  # int8 cache: quantize the new entry, merge in int8
        qk_new = jnp.clip(jnp.round(k_new.astype(jnp.float32) / KV_QSCALE),
                          -127, 127).astype(jnp.int8)
        qv_new = jnp.clip(jnp.round(v_new.astype(jnp.float32) / KV_QSCALE),
                          -127, 127).astype(jnp.int8)
    onehot = (jnp.arange(s)[None, :] == cache.length[:, None])
    oh = onehot[:, :, None, None].astype(cache.k.dtype)
    k = cache.k * (1 - oh) + oh * (qk_new if quant else k_new)
    v = cache.v * (1 - oh) + oh * (qv_new if quant else v_new)
    # flash-decode: keep the cache sequence-sharded through the attention math
    k = shard(k, "batch", "kv_seq", None, None)
    v = shard(v, "batch", "kv_seq", None, None)
    if quant:
        k_att = (k.astype(x.dtype) * KV_QSCALE)
        v_att = (v.astype(x.dtype) * KV_QSCALE)
    else:
        k_att, v_att = k, v

    ki = jnp.arange(s)[None, :]
    valid = ki <= cache.length[:, None]  # includes the newly written slot
    if window is not None:
        valid &= ki > (cache.length[:, None] - window)
    mask = valid[:, None, None, None, :]  # [B,1,1,1,S]

    out = attend(q, k_att, v_att, mask, hd)
    out = out.reshape(b, 1, n_heads * hd) @ p["wo"]
    return out, KVCache(k=k, v=v, length=cache.length + 1)


def prefill_cache(
    p: dict, x: jax.Array, *, n_heads: int, n_kv: int, hd: int,
    rope: str = "default", window: Optional[int] = None, cache_len: int | None = None,
) -> tuple[jax.Array, KVCache]:
    """Prefill: full causal attention AND build the cache for subsequent decode."""
    b, t, _ = x.shape
    positions = jnp.arange(t)[None, :]
    q = _split_heads(x @ p["wq"], n_heads, hd)
    k = _split_heads(x @ p["wk"], n_kv, hd)
    v = _split_heads(x @ p["wv"], n_kv, hd)
    q = apply_rope(q, positions, rope)
    k = apply_rope(k, positions, rope)
    q = shard(q, "batch", None, "heads", None)
    out = attend_chunked(q, k, v, hd=hd, causal=True, window=window)
    out = shard(out, "batch", None, "heads", None)
    out = out.reshape(b, t, n_heads * hd) @ p["wo"]
    s = cache_len or t
    pad = s - t
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, KVCache(k=kc, v=vc, length=jnp.full((b,), t, jnp.int32))
