"""The paper's own benchmark models (§5, Table 1) in JAX.

Parameter counts reproduce Table 1 exactly:
    MNIST-MLP   159,010     = MLP 784-200-10
    MNIST-CNN   582,026     = conv5x5x32 -> pool -> conv5x5x64 -> pool -> 1024-512-10
    CIFAR-MLP   5,852,170   = MLP 3072-1536-690-102-10 (hidden split inferred to
                              match the published total; the paper reports only
                              the total parameter size)
    CIFAR-VGG16 14,728,266  = VGG16 conv stack + BatchNorm + 512-10 classifier

All are pure functions: init_fn(key) -> params, apply_fn(params, x) -> logits.
BatchNorm runs in inference-free "training mode" per batch (batch statistics),
which is the standard simplification for FL experiments at this scale.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PaperModel:
    name: str
    init: Callable
    apply: Callable
    input_shape: tuple
    n_classes: int = 10


def _dense(key, n_in, n_out, scale: float = 1.0):
    s = scale * (2.0 / n_in) ** 0.5
    return {"w": s * jax.random.normal(key, (n_in, n_out)),
            "b": jnp.zeros((n_out,))}


def _conv(key, kh, kw, cin, cout):
    s = (2.0 / (kh * kw * cin)) ** 0.5
    return {"w": s * jax.random.normal(key, (kh, kw, cin, cout)),
            "b": jnp.zeros((cout,))}


def _bn(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _apply_bn(p, x, eps=1e-5):
    mu = x.mean(axis=(0, 1, 2), keepdims=True)
    var = x.var(axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def _conv2d(x, w, b, padding="SAME"):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


# ------------------------------------------------------------------ MLPs
def make_mlp(dims) -> PaperModel:
    def init(key):
        ks = jax.random.split(key, len(dims) - 1)
        return {f"l{i}": _dense(ks[i], dims[i], dims[i + 1])
                for i in range(len(dims) - 1)}

    def apply(p, x):
        h = x.reshape(x.shape[0], -1)
        for i in range(len(dims) - 1):
            h = h @ p[f"l{i}"]["w"] + p[f"l{i}"]["b"]
            if i < len(dims) - 2:
                h = jax.nn.relu(h)
        return h

    shape = (32, 32, 3) if dims[0] == 3072 else (28, 28, 1)
    return PaperModel(f"mlp{dims}", init, apply, shape)


MNIST_MLP = make_mlp((784, 200, 10))
CIFAR_MLP = make_mlp((3072, 1536, 690, 102, 10))


# ------------------------------------------------------------------ MNIST CNN
def _mnist_cnn_init(key):
    ks = jax.random.split(key, 4)
    return {
        "c1": _conv(ks[0], 5, 5, 1, 32),
        "c2": _conv(ks[1], 5, 5, 32, 64),
        "f1": _dense(ks[2], 1024, 512, scale=0.5),
        "f2": _dense(ks[3], 512, 10, scale=0.1),  # small head: sane init loss
    }


def _mnist_cnn_apply(p, x):
    h = jax.nn.relu(_conv2d(x, p["c1"]["w"], p["c1"]["b"], "VALID"))  # 24
    h = _pool(h)                                                       # 12
    h = jax.nn.relu(_conv2d(h, p["c2"]["w"], p["c2"]["b"], "VALID"))   # 8
    h = _pool(h)                                                       # 4
    h = h.reshape(h.shape[0], -1)                                      # 1024
    h = jax.nn.relu(h @ p["f1"]["w"] + p["f1"]["b"])
    return h @ p["f2"]["w"] + p["f2"]["b"]


MNIST_CNN = PaperModel("mnist_cnn", _mnist_cnn_init, _mnist_cnn_apply,
                       (28, 28, 1))


# ------------------------------------------------------------------ VGG16+BN
_VGG_CFG = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
            512, 512, 512, "M", 512, 512, 512, "M"]


def _vgg_init(key):
    params = {}
    cin, i = 3, 0
    keys = jax.random.split(key, 16)
    for v in _VGG_CFG:
        if v == "M":
            continue
        params[f"c{i}"] = _conv(keys[i], 3, 3, cin, v)
        params[f"bn{i}"] = _bn(v)
        cin, i = v, i + 1
    params["head"] = _dense(keys[14], 512, 10)
    return params


def _vgg_apply(p, x):
    h, i = x, 0
    for v in _VGG_CFG:
        if v == "M":
            h = _pool(h)
            continue
        h = _conv2d(h, p[f"c{i}"]["w"], p[f"c{i}"]["b"])
        h = jax.nn.relu(_apply_bn(p[f"bn{i}"], h))
        i += 1
    h = h.reshape(h.shape[0], -1)          # 1x1x512 after 5 pools on 32x32
    return h @ p["head"]["w"] + p["head"]["b"]


CIFAR_VGG16 = PaperModel("cifar_vgg16", _vgg_init, _vgg_apply, (32, 32, 3))

PAPER_MODELS = {
    "mnist_mlp": MNIST_MLP,
    "mnist_cnn": MNIST_CNN,
    "cifar_mlp": CIFAR_MLP,
    "cifar_vgg16": CIFAR_VGG16,
}

# Table 1 published parameter sizes
TABLE1_PARAMS = {
    "mnist_mlp": 159_010,
    "mnist_cnn": 582_026,
    "cifar_mlp": 5_852_170,
    "cifar_vgg16": 14_728_266,
}


def cross_entropy_loss(model: PaperModel):
    def loss_fn(params, batch):
        x, y = batch
        logits = model.apply(params, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

    return loss_fn


def accuracy(model: PaperModel, params, x, y, batch=500) -> float:
    correct = 0
    for i in range(0, len(x), batch):
        logits = model.apply(params, x[i:i + batch])
        correct += int((jnp.argmax(logits, -1) == y[i:i + batch]).sum())
    return correct / len(x)
