"""Architecture-generic model: init / train loss / prefill / decode for every
assigned family (dense, moe, vlm, audio encoder, hybrid mamba2, xlstm).

Layout decisions that matter at scale (see DESIGN.md §6):
  * layers are stacked and traversed with lax.scan (+ jax.checkpoint remat) so HLO
    size and compile time are O(1) in depth;
  * the residual stream between blocks is sequence-sharded over the 'model' axis
    (Megatron-style sequence parallelism) so the 100-layer x 4k-token carry fits;
  * attention materializes scores only per query-chunk (lax.map) — the XLA stand-in
    for the Pallas flash kernel (kernels/flash_attention.py) used on real TPU;
  * the cross-entropy is computed per sequence-chunk with vocab sharded, so the
    202k-vocab logits tensor never exists in full.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import KVCache
from repro.models.layers import apply_mlp, apply_norm, embed_init
from repro.models.sharding import shard

init_attention = attn.init_attention


# ------------------------------------------------------------------ block init
def _init_self_block(key, cfg: ArchConfig, dtype) -> dict:
    from repro.models.layers import init_mlp, init_norm

    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": init_norm(cfg.d_model, cfg.norm, dtype),
        "attn": init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, dtype),
        "mlp_norm": init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if cfg.family == "moe" and cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(ks[1], cfg.d_model, cfg.moe, cfg.act, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def _init_cross_block(key, cfg: ArchConfig, dtype) -> dict:
    from repro.models.layers import init_mlp, init_norm

    ks = jax.random.split(key, 2)
    return {
        "attn_norm": init_norm(cfg.d_model, cfg.norm, dtype),
        "attn": init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, dtype, cross=True),
        "mlp_norm": init_norm(cfg.d_model, cfg.norm, dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _init_ssm_block(key, cfg: ArchConfig, dtype) -> dict:
    from repro.models.layers import init_norm

    return {
        "norm": init_norm(cfg.d_model, cfg.norm, dtype),
        "ssm": ssm_mod.init_ssm(key, cfg.d_model, cfg.ssm, dtype),
    }


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    from repro.models.layers import init_norm

    params: dict = {"final_norm": init_norm(cfg.d_model, cfg.norm, dtype)}
    if cfg.family != "audio":
        params["embed"] = embed_init(keys[0], cfg.vocab, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[1], cfg.vocab, cfg.d_model, dtype).T

    if cfg.xlstm:
        n_s = (cfg.n_layers + 1) // 2
        n_m = cfg.n_layers // 2
        params["slstm"] = jax.vmap(
            lambda k: xlstm_mod.init_slstm(k, cfg.d_model, cfg.n_heads, dtype)
        )(jax.random.split(keys[2], n_s))
        params["mlstm"] = jax.vmap(
            lambda k: xlstm_mod.init_mlstm(k, cfg.d_model, cfg.n_heads, dtype)
        )(jax.random.split(keys[3], n_m))
    elif cfg.family == "vlm":
        n_super = cfg.n_layers // (cfg.cross_attn_every + 1)
        params["self_blocks"] = jax.vmap(jax.vmap(
            lambda k: _init_self_block(k, cfg, dtype)
        ))(jax.random.split(keys[2], (n_super, cfg.cross_attn_every)))
        params["cross_blocks"] = jax.vmap(
            lambda k: _init_cross_block(k, cfg, dtype)
        )(jax.random.split(keys[3], n_super))
    elif cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.shared_attn_every
        params["ssm_blocks"] = jax.vmap(jax.vmap(
            lambda k: _init_ssm_block(k, cfg, dtype)
        ))(jax.random.split(keys[2], (n_super, cfg.shared_attn_every)))
        params["shared_block"] = _init_self_block(keys[3], cfg, dtype)
    else:  # dense / moe / audio — uniform stack
        params["blocks"] = jax.vmap(
            lambda k: _init_self_block(k, cfg, dtype)
        )(jax.random.split(keys[2], cfg.n_layers))
    return params


def lm_head_weight(params: dict, cfg: ArchConfig) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# ------------------------------------------------------------- block forwards
def _self_block(p: dict, cfg: ArchConfig, x: jax.Array, *, causal: bool,
                window: Optional[int]) -> tuple[jax.Array, jax.Array]:
    """Pre-norm attention + MLP/MoE. Returns (x, aux_loss)."""
    h = apply_norm(p["attn_norm"], x, cfg.norm)
    h = shard(h, "batch", None, None)
    a = attn.self_attention(
        p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
        rope=cfg.rope, causal=causal, window=window)
    x = x + a
    h = apply_norm(p["mlp_norm"], x, cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        out = moe_mod.apply_moe(p["moe"], h, cfg.moe)
        x = x + out.y
        aux = out.aux_loss
    else:
        x = x + apply_mlp(p["mlp"], h, cfg.act)
    x = shard(x, "batch", "seq", None)
    return x, aux


def _cross_block(p: dict, cfg: ArchConfig, x: jax.Array,
                 kv_src: jax.Array) -> jax.Array:
    h = apply_norm(p["attn_norm"], x, cfg.norm)
    x = x + attn.cross_attention(p["attn"], h, kv_src, n_heads=cfg.n_heads,
                                 n_kv=cfg.n_kv_heads, hd=cfg.hd)
    h = apply_norm(p["mlp_norm"], x, cfg.norm)
    x = x + apply_mlp(p["mlp"], h, cfg.act)
    return shard(x, "batch", "seq", None)


def _ssm_block(p: dict, cfg: ArchConfig, x: jax.Array):
    h = apply_norm(p["norm"], x, cfg.norm)
    y, _ = ssm_mod.ssd_forward(p["ssm"], h, cfg.ssm)
    return shard(x + y, "batch", "seq", None)


# --------------------------------------------------------------- full forward
def forward(params: dict, cfg: ArchConfig, h: jax.Array, *,
            window: Optional[int] = None,
            image_embeds: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    """Training/prefill forward over the full stack. h: [B,T,d] embedded input.
    Returns (hidden, total_aux_loss)."""
    causal = not cfg.encoder_only
    window = window if window is not None else cfg.window
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.xlstm:
        for i in range(cfg.n_layers):
            pblk = (jax.tree_util.tree_map(lambda a: a[i // 2], params["slstm"])
                    if i % 2 == 0 else
                    jax.tree_util.tree_map(lambda a: a[i // 2], params["mlstm"]))
            if i % 2 == 0:
                y, _ = xlstm_mod.slstm_forward(pblk, h, cfg.n_heads)
            else:
                y, _ = xlstm_mod.mlstm_forward(pblk, h, cfg.n_heads)
            h = h + y
        return apply_norm(params["final_norm"], h, cfg.norm), aux_total

    if cfg.family == "vlm":
        def super_body(carry, blk):
            x, aux = carry
            self_ps, cross_p = blk

            def inner(c, bp):
                x2, a2 = c
                x2, a_new = _self_block(bp, cfg, x2, causal=causal, window=window)
                return (x2, a2 + a_new), None

            inner = jax.checkpoint(inner, prevent_cse=False)
            (x, aux), _ = jax.lax.scan(inner, (x, aux), self_ps)
            x = _cross_block(cross_p, cfg, x, image_embeds)
            return (x, aux), None

        body = jax.checkpoint(super_body, prevent_cse=False)
        (h, aux_total), _ = jax.lax.scan(
            body, (h, aux_total),
            (params["self_blocks"], params["cross_blocks"]))
    elif cfg.family == "hybrid":
        def super_body(carry, blk):
            x, aux = carry

            def inner(c, bp):
                return _ssm_block(bp, cfg, c), None

            inner = jax.checkpoint(inner, prevent_cse=False)
            x, _ = jax.lax.scan(inner, x, blk)
            x, a_new = _self_block(params["shared_block"], cfg, x,
                                   causal=causal, window=window)
            return (x, aux + a_new), None

        body = jax.checkpoint(super_body, prevent_cse=False)
        (h, aux_total), _ = jax.lax.scan(body, (h, aux_total),
                                         params["ssm_blocks"])
    else:
        def body(carry, bp):
            x, aux = carry
            x, a_new = _self_block(bp, cfg, x, causal=causal, window=window)
            return (x, aux + a_new), None

        body = jax.checkpoint(body, prevent_cse=False)
        (h, aux_total), _ = jax.lax.scan(body, (h, aux_total), params["blocks"])

    return apply_norm(params["final_norm"], h, cfg.norm), aux_total


# ----------------------------------------------------------------------- loss
def chunked_ce_loss(h: jax.Array, w_head: jax.Array, labels: jax.Array,
                    chunk: int = 128) -> jax.Array:
    """Next-token CE without materializing full [B,T,V] logits."""
    b, t, d = h.shape
    chunk = min(chunk, t)
    nc = t // chunk
    hs = h[:, : nc * chunk].reshape(b, nc, chunk, d).swapaxes(0, 1)
    ls = labels[:, : nc * chunk].reshape(b, nc, chunk).swapaxes(0, 1)

    def per_chunk(args):
        hx, lx = args
        logits = (hx @ w_head).astype(jnp.float32)
        logits = shard(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, lx[..., None], -1)[..., 0]
        return jnp.mean(lse - gold)

    # remat: backward recomputes each chunk's logits instead of saving [c, V]
    losses = jax.lax.map(jax.checkpoint(per_chunk, prevent_cse=False), (hs, ls))
    return jnp.mean(losses)


def embed_tokens(params: dict, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    h = params["embed"][tokens]
    return shard(h, "batch", "seq", None)


def train_loss(params: dict, cfg: ArchConfig, batch: dict[str, Any]) -> jax.Array:
    """batch: {'tokens' or 'frames', 'labels', ['image_embeds']}."""
    if cfg.family == "audio":
        h = batch["frames"].astype(jnp.dtype(cfg.dtype))
        h = shard(h, "batch", "seq", None)
    else:
        h = embed_tokens(params, cfg, batch["tokens"])
    h, aux = forward(params, cfg, h, image_embeds=batch.get("image_embeds"))
    ce = chunked_ce_loss(h, lm_head_weight(params, cfg), batch["labels"])
    return ce + aux


# ------------------------------------------------------------------- serving
class DecodeState(NamedTuple):
    caches: Any          # family-specific cache pytree
    cross_kv: Any        # vlm only: per-super-block (k, v) from image embeds


def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int) -> DecodeState:
    dtype = jnp.dtype(cfg.dtype)

    kv_dt = jnp.int8 if cfg.kv_dtype == "int8" else dtype

    def kv(b=batch, s=cache_len):
        return KVCache(
            k=jnp.zeros((b, s, cfg.n_kv_heads, cfg.hd), kv_dt),
            v=jnp.zeros((b, s, cfg.n_kv_heads, cfg.hd), kv_dt),
            length=jnp.zeros((b,), jnp.int32),
        )

    if cfg.xlstm:
        d_inner, dh = xlstm_mod._cell_dims(cfg.d_model, cfg.n_heads)
        n_s = (cfg.n_layers + 1) // 2
        n_m = cfg.n_layers // 2
        caches = {
            "s": tuple(jnp.zeros((n_s, batch, cfg.n_heads, dh), jnp.float32)
                       for _ in range(4)),
            "m": (jnp.zeros((n_m, batch, cfg.n_heads, dh, dh), jnp.float32),
                  jnp.zeros((n_m, batch, cfg.n_heads, dh), jnp.float32),
                  jnp.zeros((n_m, batch, cfg.n_heads), jnp.float32)),
        }
        return DecodeState(caches=caches, cross_kv=None)
    if cfg.family == "vlm":
        n_super = cfg.n_layers // (cfg.cross_attn_every + 1)
        stack = lambda c: jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_super, cfg.cross_attn_every) + x.shape), c)
        caches = stack(kv())
        d_img = (jnp.zeros((n_super, batch, cfg.n_image_tokens,
                            cfg.n_kv_heads, cfg.hd), dtype),) * 2
        return DecodeState(caches=caches, cross_kv=d_img)
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.shared_attn_every
        sc = ssm_mod.init_cache(batch, cfg.d_model, cfg.ssm, dtype)
        ssm_caches = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                x, (n_super, cfg.shared_attn_every) + x.shape), sc)
        attn_caches = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_super,) + x.shape), kv())
        return DecodeState(caches={"ssm": ssm_caches, "attn": attn_caches},
                           cross_kv=None)
    # dense / moe
    caches = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), kv())
    return DecodeState(caches=caches, cross_kv=None)


def prefill(params: dict, cfg: ArchConfig, tokens: jax.Array, cache_len: int,
            image_embeds: Optional[jax.Array] = None):
    """Full-sequence prefill producing last-position logits + decode state.

    Every family supports prefill: attention families fill KV caches, recurrent
    families (ssm/xlstm/hybrid) return their final recurrent state; the audio
    encoder has no decode phase, so its "prefill" is a full batched encode
    (logits only, state None).
    """
    window = cfg.window

    if cfg.family == "audio":
        h = tokens.astype(jnp.dtype(cfg.dtype))  # tokens == frame embeddings
        h, _ = forward(params, cfg, h)
        logits = h[:, -1:] @ lm_head_weight(params, cfg)
        return logits, None

    h = embed_tokens(params, cfg, tokens)

    if cfg.xlstm:
        new_s, new_m = [], []
        for i in range(cfg.n_layers):
            li = i // 2
            if i % 2 == 0:
                pblk = jax.tree_util.tree_map(lambda a: a[li], params["slstm"])
                y, carry = xlstm_mod.slstm_forward(pblk, h, cfg.n_heads)
                new_s.append(carry)
            else:
                pblk = jax.tree_util.tree_map(lambda a: a[li], params["mlstm"])
                y, carry = xlstm_mod.mlstm_forward(pblk, h, cfg.n_heads)
                new_m.append(carry)
            h = h + y
        caches = {
            "s": tuple(jnp.stack([c[j] for c in new_s]) for j in range(4)),
            "m": tuple(jnp.stack([c[j] for c in new_m]) for j in range(3)),
        }
        h = apply_norm(params["final_norm"], h, cfg.norm)
        return h[:, -1:] @ lm_head_weight(params, cfg), DecodeState(
            caches=caches, cross_kv=None)

    def self_prefill(bp, x):
        hn = apply_norm(bp["attn_norm"], x, cfg.norm)
        a, cache = attn.prefill_cache(
            bp["attn"], hn, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            hd=cfg.hd, rope=cfg.rope, window=window, cache_len=cache_len)
        x = x + a
        hn = apply_norm(bp["mlp_norm"], x, cfg.norm)
        if "moe" in bp:
            x = x + moe_mod.apply_moe(bp["moe"], hn, cfg.moe).y
        else:
            x = x + apply_mlp(bp["mlp"], hn, cfg.act)
        return shard(x, "batch", "seq", None), cache

    if cfg.family in ("dense", "moe"):
        def body(x, bp):
            return self_prefill(bp, x)

        h, caches = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                                 h, params["blocks"])
        state = DecodeState(caches=caches, cross_kv=None)
    elif cfg.family == "vlm":
        def super_body(x, blk):
            self_ps, cross_p = blk

            inner = jax.checkpoint(lambda x2, bp: self_prefill(bp, x2),
                                   prevent_cse=False)
            x, self_caches = jax.lax.scan(inner, x, self_ps)
            # cache the cross-attn K/V projected from the image embeddings
            kc = attn._split_heads(image_embeds @ cross_p["attn"]["wk"],
                                   cfg.n_kv_heads, cfg.hd)
            vc = attn._split_heads(image_embeds @ cross_p["attn"]["wv"],
                                   cfg.n_kv_heads, cfg.hd)
            x = _cross_block(cross_p, cfg, x, image_embeds)
            return x, (self_caches, (kc, vc))

        h, (caches, cross_kv) = jax.lax.scan(
            jax.checkpoint(super_body, prevent_cse=False), h,
            (params["self_blocks"], params["cross_blocks"]))
        state = DecodeState(caches=caches, cross_kv=cross_kv)
    elif cfg.family == "hybrid":
        def super_body(x, blk):
            def inner(x2, bp):
                hn = apply_norm(bp["norm"], x2, cfg.norm)
                y, s_final = ssm_mod.ssd_forward(bp["ssm"], hn, cfg.ssm)
                conv_tail = _conv_tail(hn, bp["ssm"], cfg)
                return x2 + y, ssm_mod.SSMCache(state=s_final, conv=conv_tail)

            inner = jax.checkpoint(inner, prevent_cse=False)
            x, ssm_caches = jax.lax.scan(inner, x, blk)
            x, attn_cache = self_prefill(params["shared_block"], x)
            return x, (ssm_caches, attn_cache)

        h, (sc, ac) = jax.lax.scan(
            jax.checkpoint(super_body, prevent_cse=False), h,
            params["ssm_blocks"])
        state = DecodeState(caches={"ssm": sc, "attn": ac}, cross_kv=None)
    else:
        raise ValueError(cfg.family)

    h = apply_norm(params["final_norm"], h, cfg.norm)
    return h[:, -1:] @ lm_head_weight(params, cfg), state


def _conv_tail(hn: jax.Array, p_ssm: dict, cfg: ArchConfig) -> jax.Array:
    """Last (d_conv-1) pre-conv xBC inputs — the rolling decode context that
    ssm.ssd_decode_step's causal conv expects."""
    spec = cfg.ssm
    d_inner = spec.expand * cfg.d_model
    gn = spec.n_groups * spec.d_state
    tail = hn[:, -(spec.d_conv - 1):, :] @ p_ssm["in_proj"]
    return tail[..., d_inner: 2 * d_inner + 2 * gn]


def decode_step(params: dict, cfg: ArchConfig, token: jax.Array,
                state: DecodeState) -> tuple[jax.Array, DecodeState]:
    """One-token decode across all families. token: int32[B, 1]."""
    window = cfg.window
    h = embed_tokens(params, cfg, token) if cfg.family != "audio" else token
    h = shard(h, "batch", None, None)

    if cfg.xlstm:
        s_cache, m_cache = state.caches["s"], state.caches["m"]
        new_s, new_m = [], []
        for i in range(cfg.n_layers):
            if i % 2 == 0:
                li = i // 2
                pblk = jax.tree_util.tree_map(lambda a: a[li], params["slstm"])
                cache = tuple(c[li] for c in s_cache)
                y, new = xlstm_mod.slstm_forward(pblk, h, cfg.n_heads, cache=cache)
                new_s.append(new)
            else:
                li = i // 2
                pblk = jax.tree_util.tree_map(lambda a: a[li], params["mlstm"])
                cache = tuple(c[li] for c in m_cache)
                y, new = xlstm_mod.mlstm_decode_step(pblk, h, cache, cfg.n_heads)
                new_m.append(new)
            h = h + y
        caches = {
            "s": tuple(jnp.stack([n[j] for n in new_s]) for j in range(4)),
            "m": tuple(jnp.stack([n[j] for n in new_m]) for j in range(3)),
        }
        h = apply_norm(params["final_norm"], h, cfg.norm)
        return h @ lm_head_weight(params, cfg), DecodeState(caches=caches,
                                                            cross_kv=None)

    def self_decode(bp, x, cache):
        hn = apply_norm(bp["attn_norm"], x, cfg.norm)
        a, cache = attn.decode_self_attention(
            bp["attn"], hn, cache, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            hd=cfg.hd, rope=cfg.rope, window=window)
        x = x + a
        hn = apply_norm(bp["mlp_norm"], x, cfg.norm)
        if "moe" in bp:
            x = x + moe_mod.apply_moe(bp["moe"], hn, cfg.moe).y
        else:
            x = x + apply_mlp(bp["mlp"], hn, cfg.act)
        return x, cache

    if cfg.family in ("dense", "moe"):
        def body(x, scan_in):
            bp, cache = scan_in
            x, cache = self_decode(bp, x, cache)
            return x, cache

        h, caches = jax.lax.scan(body, h, (params["blocks"], state.caches))
        new_state = DecodeState(caches=caches, cross_kv=None)
    elif cfg.family == "vlm":
        def cross_decode(cp, x, kv):
            hn = apply_norm(cp["attn_norm"], x, cfg.norm)
            k, v = kv
            q = attn._split_heads(hn @ cp["attn"]["wq"], cfg.n_heads, cfg.hd)
            o = attn.attend(q, k, v, None, cfg.hd)
            x = x + o.reshape(*x.shape[:-1], -1) @ cp["attn"]["wo"]
            hn = apply_norm(cp["mlp_norm"], x, cfg.norm)
            return x + apply_mlp(cp["mlp"], hn, cfg.act)

        def super_body(x, scan_in):
            self_ps, cross_p, self_caches, ckv = scan_in

            def inner(x2, si):
                bp, cache = si
                x2, cache = self_decode(bp, x2, cache)
                return x2, cache

            x, self_caches = jax.lax.scan(inner, x, (self_ps, self_caches))
            x = cross_decode(cross_p, x, ckv)
            return x, (self_caches, None)

        h, (caches, _) = jax.lax.scan(
            super_body, h,
            (params["self_blocks"], params["cross_blocks"], state.caches,
             state.cross_kv))
        new_state = DecodeState(caches=caches, cross_kv=state.cross_kv)
    elif cfg.family == "hybrid":
        def super_body(x, scan_in):
            ssm_ps, ssm_caches, attn_cache = scan_in

            def inner(x2, si):
                bp, cache = si
                hn = apply_norm(bp["norm"], x2, cfg.norm)
                y, cache = ssm_mod.ssd_decode_step(bp["ssm"], hn, cache, cfg.ssm)
                return x2 + y, cache

            x, ssm_caches = jax.lax.scan(inner, x, (ssm_ps, ssm_caches))
            x, attn_cache = self_decode(params["shared_block"], x, attn_cache)
            return x, (ssm_caches, attn_cache)

        h, (sc, ac) = jax.lax.scan(
            super_body, h,
            (params["ssm_blocks"], state.caches["ssm"], state.caches["attn"]))
        new_state = DecodeState(caches={"ssm": sc, "attn": ac}, cross_kv=None)
    else:
        raise ValueError(f"decode unsupported for family {cfg.family}")

    h = apply_norm(params["final_norm"], h, cfg.norm)
    logits = h @ lm_head_weight(params, cfg)
    return logits, new_state


def param_count(params: dict) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
