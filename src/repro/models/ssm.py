"""Mamba2 (SSD — state space duality) block, chunked-parallel + one-step decode.

Scalar-per-head decay (a_t = exp(dt_t * A_h)), multi-head state S in R^{N x P}.
Training/prefill uses the chunked SSD algorithm: quadratic attention-like form
within chunks of length Q, linear state recurrence across chunks via lax.scan.
Decode is the O(1) recurrent update carried in SSMCache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMSpec
from repro.models.layers import dense_init
from repro.models.sharding import shard


class SSMCache(NamedTuple):
    state: jax.Array       # [B, H, N, P]
    conv: jax.Array        # [B, d_conv-1, conv_channels] rolling conv context


def dims(d_model: int, spec: SSMSpec):
    d_inner = spec.expand * d_model
    n_heads = d_inner // spec.head_dim
    conv_ch = d_inner + 2 * spec.n_groups * spec.d_state
    return d_inner, n_heads, conv_ch


def init_ssm(key, d_model: int, spec: SSMSpec, dtype) -> dict:
    d_inner, n_heads, conv_ch = dims(d_model, spec)
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_inner + 2 * spec.n_groups * spec.d_state + n_heads
    return {
        "in_proj": dense_init(ks[0], d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.d_conv, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "out_proj": dense_init(ks[4], d_inner, d_model, dtype),
    }


def _split_proj(zxbcdt: jax.Array, d_inner: int, spec: SSMSpec, n_heads: int):
    gn = spec.n_groups * spec.d_state
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner: 2 * d_inner + 2 * gn]
    dt = zxbcdt[..., 2 * d_inner + 2 * gn:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xbc: [B, T, C]; w: [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_forward(p: dict, x: jax.Array, spec: SSMSpec,
                init_state: jax.Array | None = None):
    """Chunked SSD scan. x: [B, T, d_model] -> (y, final_state)."""
    b, t, d_model = x.shape
    d_inner, n_heads, conv_ch = dims(d_model, spec)
    g, n, pdim, q = spec.n_groups, spec.d_state, spec.head_dim, spec.chunk
    if t % q != 0:  # odd lengths (tests, prompts): largest divisor <= chunk
        q = next(d for d in range(min(q, t), 0, -1) if t % d == 0)
    nc = t // q

    z, xbc, dt = _split_proj(x @ p["in_proj"], d_inner, spec, n_heads)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_inner].reshape(b, t, n_heads, pdim)
    Bv = xbc[..., d_inner: d_inner + g * n].reshape(b, t, g, n)
    Cv = xbc[..., d_inner + g * n:].reshape(b, t, g, n)
    heads_per_g = n_heads // g
    Bh = jnp.repeat(Bv, heads_per_g, axis=2)  # [B,T,H,N]
    Ch = jnp.repeat(Cv, heads_per_g, axis=2)

    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # [B,T,H]
    A = -jnp.exp(p["A_log"])                                          # [H] (negative)
    loga = (dtv * A).astype(jnp.float32)                              # log decay, <=0

    # reshape into chunks; heads over 'model' so the [Q,Q,H] intra-chunk score
    # tensor stays device-local
    def ch(a):
        return a.reshape(b, nc, q, *a.shape[2:])
    xs_c, B_c, C_c, loga_c, dt_c = map(ch, (xs, Bh, Ch, loga, dtv))
    xs_c = shard(xs_c, "batch", None, None, "heads", None)
    B_c = shard(B_c, "batch", None, None, "heads", None)
    C_c = shard(C_c, "batch", None, None, "heads", None)
    loga_c = shard(loga_c, "batch", None, None, "heads")
    dt_c = shard(dt_c, "batch", None, None, "heads")

    cum = jnp.cumsum(loga_c, axis=2)                                  # [B,nc,Q,H]
    # intra-chunk (attention-like) term; mask BEFORE exp (0*inf NaN in backward)
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]               # [B,nc,Qq,Qk,H]
    causal = jnp.tril(jnp.ones((q, q), bool))
    rel = jnp.where(causal[None, None, :, :, None], rel, -jnp.inf)
    gamma = jnp.exp(rel)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", C_c, B_c) * gamma
    y_intra = jnp.einsum("bcqkh,bckhp,bckh->bcqhp", scores, xs_c, dt_c)

    # per-chunk input -> state contribution
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)                   # [B,nc,Q,H]
    chunk_state = jnp.einsum("bcqhn,bcqhp,bcqh,bcqh->bchnp",
                             B_c, xs_c, dt_c, decay_to_end)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                           # [B,nc,H]

    s0 = (init_state if init_state is not None
          else jnp.zeros((b, n_heads, n, pdim), jnp.float32))

    def scan_fn(s, inp):
        cs, cd = inp  # [B,H,N,P], [B,H]
        s_next = s * cd[..., None, None] + cs
        return s_next, s

    (s_final, s_prevs) = jax.lax.scan(
        scan_fn, s0, (chunk_state.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    s_prevs = s_prevs.swapaxes(0, 1)                                  # [B,nc,H,N,P]

    # inter-chunk: contribution of carried state to each position
    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp",
                         C_c, s_prevs, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, t, n_heads, pdim)
    y = y + xs * p["D"][None, None, :, None]
    y = y.reshape(b, t, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], s_final


def ssd_decode_step(p: dict, x: jax.Array, cache: SSMCache, spec: SSMSpec):
    """One-token recurrent update. x: [B, 1, d_model]."""
    b, t, d_model = x.shape
    d_inner, n_heads, conv_ch = dims(d_model, spec)
    g, n, pdim = spec.n_groups, spec.d_state, spec.head_dim

    z, xbc, dt = _split_proj(x @ p["in_proj"], d_inner, spec, n_heads)
    # rolling causal conv: context = last (K-1) inputs + current
    ctx = jnp.concatenate([cache.conv, xbc], axis=1)                  # [B,K,C]
    xbc_t = jax.nn.silu(jnp.einsum("bkc,kc->bc", ctx, p["conv_w"]) + p["conv_b"])
    new_conv = ctx[:, 1:, :]

    xs = xbc_t[:, :d_inner].reshape(b, n_heads, pdim)
    Bv = xbc_t[:, d_inner: d_inner + g * n].reshape(b, g, n)
    Cv = xbc_t[:, d_inner + g * n:].reshape(b, g, n)
    heads_per_g = n_heads // g
    Bh = jnp.repeat(Bv, heads_per_g, axis=1)                          # [B,H,N]
    Ch = jnp.repeat(Cv, heads_per_g, axis=1)

    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = jnp.exp(dtv * (-jnp.exp(p["A_log"])))                         # [B,H]
    s = cache.state * a[..., None, None] + jnp.einsum(
        "bhn,bhp,bh->bhnp", Bh, xs.astype(jnp.float32), dtv)
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), s)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, 1, d_inner).astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], SSMCache(state=s, conv=new_conv)


def init_cache(batch: int, d_model: int, spec: SSMSpec, dtype) -> SSMCache:
    d_inner, n_heads, conv_ch = dims(d_model, spec)
    return SSMCache(
        state=jnp.zeros((batch, n_heads, spec.d_state, spec.head_dim), jnp.float32),
        conv=jnp.zeros((batch, spec.d_conv - 1, conv_ch), dtype),
    )
