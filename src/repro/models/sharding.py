"""Logical-axis sharding annotations for model code.

Model forward functions call ``shard(x, 'batch', None, 'model')`` with *logical*
axis names; the launcher installs a mapping from logical names to physical mesh
axes (``('pod','data')`` / ``'model'``). Outside a mesh context (unit tests,
smoke tests, single-device benchmarks) the calls are identity — the same model
code runs everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def logical_axis_rules(mesh: Mesh, rules: dict[str, Union[str, tuple, None]]):
    """rules: logical name -> physical mesh axis (or tuple of axes, or None)."""
    prev_r, prev_m = _rules(), _mesh()
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev_r, prev_m


def spec(*logical: Optional[str]) -> P:
    rules = _rules() or {}
    return P(*[rules.get(ax) if ax is not None else None for ax in logical])


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the sharding implied by logical axis names (or no-op)."""
    mesh = _mesh()
    if mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"rank mismatch: {len(logical)} names for shape {x.shape}")
    # Inside a partial-manual shard_map region the constraint must be built on
    # the CONTEXT mesh (some axes Manual), not the concrete all-Auto mesh, or
    # XLA rejects it with a mesh mismatch. The logical rules already exclude
    # manual (federation) axes from every spec.
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names == mesh.axis_names:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(am, spec(*logical)))
    except Exception:
        pass
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec(*logical)))


def param_sharding(path_names: Sequence[Optional[str]]) -> P:
    return spec(*path_names)
