"""Mixture-of-Experts layer: shared + routed experts, sort-based capacity dispatch.

Token-choice top-k routing (DeepSeek-MoE / Llama-4 style). Dispatch avoids the
GShard one-hot tensor (T x E x C is infeasible at 1M tokens): assignments are
argsort-grouped by expert, positions within each expert computed by searchsorted,
overflow beyond the static capacity dropped (standard capacity-factor semantics).
Expert weight tensors carry a leading E dim that shards over the 'model' mesh axis
(expert parallelism); the scatter/gather between token space (data-sharded) and
expert space (model-sharded) is GSPMD's to lower into all-to-alls.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoESpec
from repro.models.layers import dense_init
from repro.models.sharding import shard


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array  # load-balance auxiliary loss (Switch-style)


def init_moe(key, d: int, spec: MoESpec, act: str, dtype) -> dict:
    ks = jax.random.split(key, 7)
    e, f = spec.n_experts, spec.d_ff_expert
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "wi_gate": (dense_init(ks[1], d, f, dtype)[None] *
                    jnp.ones((e, 1, 1), dtype)),
        "wi_up": (dense_init(ks[2], d, f, dtype)[None] *
                  jnp.ones((e, 1, 1), dtype)),
        "wo": (dense_init(ks[3], f, d, dtype)[None] *
               jnp.ones((e, 1, 1), dtype)),
    }
    if spec.n_shared:
        fs = spec.n_shared * f
        p["shared_wi_gate"] = dense_init(ks[4], d, fs, dtype)
        p["shared_wi_up"] = dense_init(ks[5], d, fs, dtype)
        p["shared_wo"] = dense_init(ks[6], fs, d, dtype)
    return p


def capacity(n_tokens: int, spec: MoESpec) -> int:
    c = int(math.ceil(n_tokens * spec.top_k / spec.n_experts * spec.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def _dispatch_group(xt, eidx, gate_vals, e: int, k: int, cap: int):
    """Sort-based dispatch for ONE group's tokens [T, d]. All ops are local to
    the group, so vmapping over groups keeps the sort device-local under GSPMD
    (a flat global argsort would be a cross-device sort — observed 20x memory
    blowup)."""
    t, d = xt.shape
    flat_e = eidx.reshape(-1)                                 # [t*k]
    flat_tok = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, stok, sgate = flat_e[order], flat_tok[order], flat_gate[order]
    seg_start = jnp.searchsorted(se, jnp.arange(e))
    pos = jnp.arange(t * k) - seg_start[se]
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)           # overflow slot
    # .add, not .set: slots are unique by construction, and scatter-set with
    # potentially-duplicate indices lowers to a sort-with-payload (observed
    # multi-GiB u32/f32 sort buffers); scatter-add stays a plain scatter.
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].add(
        xt[stok], indices_are_sorted=True, unique_indices=True)
    return buf[:-1].reshape(e, cap, d), (keep, slot, stok, sgate)


def _combine_group(yexp, dispatch, t: int, d: int, e: int, cap: int, dtype):
    keep, slot, stok, sgate = dispatch
    flat = yexp.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None],
                         flat[jnp.clip(slot, 0, e * cap - 1)], 0.0)
    return jnp.zeros((t, d), dtype).at[stok].add(
        (sgate[:, None] * gathered).astype(dtype))


def apply_moe(p: dict, x: jax.Array, spec: MoESpec) -> MoEOut:
    """x: [B, T, d] -> [B, T, d]. B is the dispatch-group dim (data-sharded)."""
    b, t, d = x.shape
    e, k = spec.n_experts, spec.top_k

    logits = (x.astype(jnp.float32) @ p["router"])            # [B, T, E]
    probs = jax.nn.softmax(logits, -1)
    gate_vals, eidx = jax.lax.top_k(probs, k)                 # [B, T, k]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (mean prob * fraction routed, Switch-style)
    me = probs.mean((0, 1))                                   # [E]
    ce = jnp.zeros((e,)).at[eidx.reshape(-1)].add(1.0) / (b * t * k)
    aux = spec.router_aux_weight * e * jnp.sum(me * ce)

    # ---- per-group sort-based dispatch with static per-group capacity
    cap = capacity(t, spec)
    buf, dispatch = jax.vmap(
        lambda xg, eg, gg: _dispatch_group(xg, eg, gg, e, k, cap)
    )(x, eidx, gate_vals)                                     # buf [B, E, cap, d]
    buf = shard(buf, "batch", "expert", None, None)

    # ---- expert computation (E sharded over 'model' => expert parallel;
    # the B<->E resharding of buf is the all-to-all)
    h = jnp.einsum("gecd,edf->gecf", buf, p["wi_gate"])
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", buf, p["wi_up"])
    yexp = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    yexp = shard(yexp, "batch", "expert", None, None)

    y = jax.vmap(
        lambda ye, disp: _combine_group(ye, disp, t, d, e, cap, x.dtype)
    )(yexp, dispatch)

    if "shared_wi_gate" in p:
        y = y + (jax.nn.silu(x @ p["shared_wi_gate"]) *
                 (x @ p["shared_wi_up"])) @ p["shared_wo"]
    return MoEOut(y=y.reshape(b, t, d), aux_loss=aux)
