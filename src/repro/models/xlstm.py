"""xLSTM blocks (arXiv:2405.04517): sLSTM (scalar memory, exponential gating with
stabilizer) and mLSTM (matrix memory, covariance update).

Training/prefill:
  * mLSTM runs in the CHUNKED-PARALLEL form (the paper's training mode): the
    per-step matrix state C [dh,dh] is never materialized per position — only
    per chunk — which is what makes 4k-step training memory-feasible. The
    unstabilized chunked math is exactly equal to the stabilized recurrence
    (the stabilizer cancels analytically; h = Cq / max(|n·q|, 1)).
  * sLSTM has no parallel form (true nonlinear recurrence); it runs as a
    two-level remat scan (outer chunks checkpointed, inner steps recomputed in
    backward) so only O(T/chunk) states are saved.

Decode: O(1) recurrent steps for both cell types, carrying (c,n,m,h) / (C,n,m).
Blocks alternate sLSTM (even index) / mLSTM (odd). The assignment's d_ff=0
means no separate FFN: each cell carries its own factor-2 up/down projection.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.sharding import shard

CHUNK_T = 128   # sLSTM remat chunk (outer scan length = T / CHUNK_T)
CHUNK_M = 256   # mLSTM chunked-parallel chunk length


def _cell_dims(d_model: int, n_heads: int, factor: int = 2):
    d_inner = factor * d_model
    dh = d_inner // n_heads
    return d_inner, dh


def init_slstm(key, d_model: int, n_heads: int, dtype) -> dict:
    d_inner, dh = _cell_dims(d_model, n_heads)
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], d_model, 4 * d_inner, dtype),   # z,i,f,o pre-acts
        "r": (jax.random.normal(ks[1], (n_heads, dh, 4 * dh)) * 0.05).astype(dtype),
        "b": jnp.zeros((4 * d_inner,), dtype),
        "w_out": dense_init(ks[3], d_inner, d_model, dtype),
    }


def init_mlstm(key, d_model: int, n_heads: int, dtype) -> dict:
    d_inner, dh = _cell_dims(d_model, n_heads)
    ks = jax.random.split(key, 6)
    return {
        "w_qkv": dense_init(ks[0], d_model, 3 * d_inner, dtype),
        "w_if": dense_init(ks[1], d_model, 2 * n_heads, dtype),  # input/forget gates
        "w_o": dense_init(ks[2], d_model, d_inner, dtype),
        "w_out": dense_init(ks[3], d_inner, d_model, dtype),
    }


# ------------------------------------------------------------------- sLSTM
def _slstm_step(r, b_heads, n_heads, dh):
    def step(carry, pre_t):  # pre_t: [B, 4, H, dh]
        c, n, m, h = carry
        bsz = h.shape[0]
        rec = jnp.einsum("bhd,hde->bhe", h, r).reshape(bsz, n_heads, 4, dh)
        rec = jnp.moveaxis(rec, 2, 1)                       # [B,4,H,dh]
        zt, it, ft, ot = [pre_t.astype(jnp.float32)[:, j] + rec[:, j]
                          for j in range(4)]
        m_new = jnp.maximum(ft + m, it)                     # stabilizer
        i_g = jnp.exp(it - m_new)
        f_g = jnp.exp(ft + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(zt)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    return step


def slstm_forward(p: dict, x: jax.Array, n_heads: int,
                  cache: tuple | None = None):
    """x: [B,T,d]. Two-level remat scan; returns (y, final_state)."""
    b, t, d_model = x.shape
    d_inner, dh = _cell_dims(d_model, n_heads)
    pre = (x @ p["w_in"] + p["b"]).reshape(b, t, 4, n_heads, dh)

    if cache is None:
        c0 = jnp.zeros((b, n_heads, dh), jnp.float32)
        n0 = jnp.ones((b, n_heads, dh), jnp.float32)
        m0 = jnp.zeros((b, n_heads, dh), jnp.float32)
        h0 = jnp.zeros((b, n_heads, dh), jnp.float32)
    else:
        c0, n0, m0, h0 = cache

    step = _slstm_step(p["r"].astype(jnp.float32), p["b"], n_heads, dh)
    xs = jnp.moveaxis(pre, 1, 0)                            # [T,B,4,H,dh]

    if t > CHUNK_T and t % CHUNK_T == 0:
        xs = xs.reshape(t // CHUNK_T, CHUNK_T, *xs.shape[1:])

        def outer(carry, xc):
            carry, hs = jax.lax.scan(step, carry, xc)
            return carry, hs

        carry, hs = jax.lax.scan(
            jax.checkpoint(outer, prevent_cse=False), (c0, n0, m0, h0), xs)
        hs = hs.reshape(t, b, n_heads, dh)
    else:
        carry, hs = jax.lax.scan(step, (c0, n0, m0, h0), xs)

    y = jnp.moveaxis(hs, 0, 1).reshape(b, t, d_inner).astype(x.dtype)
    return y @ p["w_out"], carry


# ------------------------------------------------------------------- mLSTM
def _mlstm_proj(p, x, n_heads):
    b, t, d_model = x.shape
    d_inner, dh = _cell_dims(d_model, n_heads)
    qkv = (x @ p["w_qkv"]).reshape(b, t, 3, n_heads, dh)
    gif = (x @ p["w_if"]).reshape(b, t, 2, n_heads).astype(jnp.float32)
    o = jax.nn.sigmoid(x @ p["w_o"]).reshape(b, t, n_heads, dh)
    q = qkv[:, :, 0].astype(jnp.float32)
    k = qkv[:, :, 1].astype(jnp.float32) * (dh ** -0.5)
    v = qkv[:, :, 2].astype(jnp.float32)
    logi = gif[:, :, 0]                      # input gate pre-act (exp gate)
    logf = jax.nn.log_sigmoid(gif[:, :, 1])  # forget gate in log space
    return q, k, v, logi, logf, o, dh, d_inner


def mlstm_forward(p: dict, x: jax.Array, n_heads: int,
                  cache: tuple | None = None):
    """Chunked-parallel mLSTM (training/prefill). x: [B,T,d].

    Returns (y, (C, n, m)) — m is returned as zeros (the chunked form is
    unstabilized-exact; the recurrent decode step re-stabilizes from m=0).
    """
    b, t, d_model = x.shape
    q, k, v, logi, logf, o, dh, d_inner = _mlstm_proj(p, x, n_heads)

    if cache is None:
        C0 = jnp.zeros((b, n_heads, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, n_heads, dh), jnp.float32)
    else:
        C0, n0, m0 = cache
        # fold the stabilizer back in: unstabilized state = exp(m) * stabilized
        C0 = C0 * jnp.exp(m0)[..., None, None]
        n0 = n0 * jnp.exp(m0)[..., None]

    Q = CHUNK_M if (t % CHUNK_M == 0 and t >= CHUNK_M) else t
    nc = t // Q

    def chunked(a):
        return a.reshape(b, nc, Q, *a.shape[2:])

    qc, kc, vc = map(chunked, (q, k, v))
    lic, lfc = map(chunked, (logi, logf))
    qc = shard(qc, "batch", None, None, "heads", None)
    kc = shard(kc, "batch", None, None, "heads", None)
    vc = shard(vc, "batch", None, None, "heads", None)

    csum = jnp.cumsum(lfc, axis=2)                    # [B,nc,Q,H]
    total = csum[:, :, -1, :]                         # [B,nc,H]

    # intra-chunk: w_ab = exp(b_a - b_b + logi_b) for b <= a.
    # Mask BEFORE the exp: masked rel is large-positive, and exp->inf inside a
    # where() turns the backward pass into 0*inf = NaN.
    rel = csum[:, :, :, None, :] - csum[:, :, None, :, :] + lic[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    rel = jnp.where(causal[None, None, :, :, None], rel, -jnp.inf)
    w = jnp.exp(rel)
    qk = jnp.einsum("zcahd,zcbhd->zcabh", qc, kc)     # [B,nc,Qa,Qb,H]
    scores = qk * w
    y_intra = jnp.einsum("zcabh,zcbhd->zcahd", scores, vc)
    den_intra = jnp.sum(scores, axis=3)               # [B,nc,Qa,H]

    # chunk state contributions
    wst = jnp.exp(total[:, :, None, :] - csum + lic)  # [B,nc,Q,H]
    Cc = jnp.einsum("bcqh,bcqhv,bcqhk->bchvk", wst, vc, kc)
    ncq = jnp.einsum("bcqh,bcqhk->bchk", wst, kc)

    def scan_fn(carry, inp):
        C, n = carry
        Cc_i, nc_i, tot_i = inp
        decay = jnp.exp(tot_i)[..., None, None]
        C_new = C * decay + Cc_i
        n_new = n * decay[..., 0] + nc_i
        return (C_new, n_new), (C, n)

    (C_f, n_f), (C_prevs, n_prevs) = jax.lax.scan(
        scan_fn, (C0, n0),
        (Cc.swapaxes(0, 1), ncq.swapaxes(0, 1), total.swapaxes(0, 1)))
    C_prevs = C_prevs.swapaxes(0, 1)                  # [B,nc,H,dh,dh]
    n_prevs = n_prevs.swapaxes(0, 1)                  # [B,nc,H,dh]

    eb = jnp.exp(csum)                                # [B,nc,Q,H]
    y_inter = jnp.einsum("bcqh,bcqhk,bchvk->bcqhv", eb, qc, C_prevs)
    den_inter = jnp.einsum("bcqh,bcqhk,bchk->bcqh", eb, qc, n_prevs)

    den = jnp.maximum(jnp.abs(den_intra + den_inter), 1.0)
    h = (y_intra + y_inter) / den[..., None]
    h = h.reshape(b, t, n_heads, dh)
    y = (o.astype(jnp.float32) * h).reshape(b, t, d_inner).astype(x.dtype)
    m_f = jnp.zeros((b, n_heads), jnp.float32)
    return y @ p["w_out"], (C_f, n_f, m_f)


def mlstm_decode_step(p: dict, x: jax.Array, cache: tuple, n_heads: int):
    """O(1) stabilized recurrent step. x: [B,1,d]."""
    b, t, d_model = x.shape
    q, k, v, logi, logf, o, dh, d_inner = _mlstm_proj(p, x, n_heads)
    C, n, m = cache
    it, ft = logi[:, 0], logf[:, 0]                   # [B,H]
    m_new = jnp.maximum(ft + m, it)
    i_g = jnp.exp(it - m_new)[..., None]
    f_g = jnp.exp(ft + m - m_new)[..., None]
    q0, k0, v0 = q[:, 0], k[:, 0], v[:, 0]
    C_new = f_g[..., None] * C + i_g[..., None] * (v0[..., :, None] *
                                                   k0[..., None, :])
    n_new = f_g * n + i_g * k0
    num = jnp.einsum("bhvk,bhk->bhv", C_new, q0)
    # h = Cq / max(|n.q|, 1) in unstabilized terms == stabilized with exp(-m)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q0)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    y = (o[:, 0].astype(jnp.float32) * h).reshape(b, 1, d_inner).astype(x.dtype)
    return y @ p["w_out"], (C_new, n_new, m_new)
