"""Native JAX optimizers (no optax dependency): SGD, momentum, AdamW.

API mirrors the usual (init, update) pair:
    opt = sgd(lr=0.1) | momentum(lr, beta) | adamw(lr, ...)
    state = opt.init(params)
    params, state = opt.step(params, grads, state)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    step: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    name: str = "opt"


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def step(params, grads, state):
        new = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        return new, state

    return Optimizer(init, step, "sgd")


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def step(params, grads, m):
        m = jax.tree_util.tree_map(lambda mi, g: beta * mi + g, m, grads)
        upd = (jax.tree_util.tree_map(lambda mi, g: beta * mi + g, m, grads)
               if nesterov else m)
        new = jax.tree_util.tree_map(lambda p, u: p - lr * u, params, upd)
        return new, m

    return Optimizer(init, step, "momentum")


class AdamState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jax.Array


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(mu=z(), nu=z(), count=jnp.zeros((), jnp.int32))

    def step(params, grads, state):
        c = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new = jax.tree_util.tree_map(upd, params, mu, nu)
        return new, AdamState(mu=mu, nu=nu, count=c)

    return Optimizer(init, step, "adamw")
