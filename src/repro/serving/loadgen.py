"""Load-generator harness for the inference server (DESIGN.md §16).

Open-loop arrival at a configured QPS: request ``i`` is *scheduled* at
``t0 + i / qps`` regardless of how previous requests fared — the honest way
to measure serving latency under load (a closed loop hides queueing by
slowing the offered rate to match the server). Payloads are drawn from a
fixed pool cycled by request index, so a run is deterministic in everything
but wall-clock timing.

Latency is stamped by the server itself (submit -> response); the generator
only paces, submits, and finally *drains* — every submitted request is
waited on, and one that never completes (or raised) counts as an error.
Zero dropped requests is a CI-gated invariant of the serve smoke.
"""
from __future__ import annotations

import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.serving.metrics import ServingMetrics
from repro.serving.server import InferenceServer


class LoadGenerator:
    """Open-loop request generator against one :class:`InferenceServer`."""

    def __init__(self, server: InferenceServer, payloads: Sequence[np.ndarray],
                 qps: float, metrics: Optional[ServingMetrics] = None,
                 wait_timeout_s: float = 60.0):
        if qps <= 0:
            raise ValueError(f"qps must be > 0, got {qps}")
        if not len(payloads):
            raise ValueError("need a non-empty payload pool")
        self.server = server
        self.payloads = payloads
        self.qps = float(qps)
        self.metrics = metrics if metrics is not None else server.metrics
        self.wait_timeout_s = wait_timeout_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._tickets: list = []
        self._t0: Optional[float] = None

    # ------------------------------------------------------------------ runs
    def run(self, n_requests: Optional[int] = None,
            duration_s: Optional[float] = None) -> int:
        """Pace requests until ``n_requests`` sent, ``duration_s`` elapsed,
        or ``stop()`` — then drain. Returns the number submitted."""
        t0 = time.perf_counter()
        self._t0 = t0
        i = 0
        while not self._stop.is_set():
            if n_requests is not None and i >= n_requests:
                break
            if duration_s is not None and \
                    time.perf_counter() - t0 >= duration_s:
                break
            target = t0 + i / self.qps
            delay = target - time.perf_counter()
            if delay > 0:
                # wait() (not sleep) so stop() interrupts the pacing promptly
                if self._stop.wait(delay):
                    break
            self._tickets.append(
                self.server.submit(self.payloads[i % len(self.payloads)]))
            i += 1
        self.metrics.wall_s = time.perf_counter() - t0
        return i

    def drain(self) -> int:
        """Wait out every in-flight request; returns the error count
        (timeouts + adapter exceptions). Request errors are recorded by the
        server; only a never-served timeout is recorded here."""
        errors = 0
        for t in self._tickets:
            try:
                t.wait(self.wait_timeout_s)
            except TimeoutError:
                self.metrics.record_error()
                errors += 1
            except Exception:
                errors += 1      # adapter error: already counted server-side
        self._tickets = []
        if self._t0 is not None:
            # pacing start -> fully drained; the CLI overwrites this with the
            # whole train+serve wall clock after everything stops
            self.metrics.wall_s = time.perf_counter() - self._t0
        return errors

    # ------------------------------------------------------------- threading
    def start(self, n_requests: Optional[int] = None,
              duration_s: Optional[float] = None) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self.run, kwargs={"n_requests": n_requests,
                                     "duration_s": duration_s},
            name="loadgen", daemon=True)
        self._thread.start()

    def stop(self) -> int:
        """Stop pacing, join, drain. Returns the drain error count."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=self.wait_timeout_s)
            self._thread = None
        return self.drain()
