"""CLI: the continuous train -> checkpoint -> hot-swap serving loop.

    python -m repro.serving --preset table2_quick --quick --qps 50 \\
        --out serve_metrics.json

Runs a ``repro.sim`` preset in the MAIN thread while an inference server
(background thread) answers classifier requests paced by an open-loop load
generator (another thread). Every finished round publishes a checkpoint;
the server's watcher stages it off the serve path and hot-swaps between
batches. After training, the loop waits until the server has swapped onto
the final published checkpoint, drains the loadgen, and writes one
``repro.serve/v1`` metrics JSON.

Exit code is non-zero when any request was dropped/errored or (unless
``--allow-no-swap``) no hot swap happened — the serve-smoke CI job runs
this binary directly.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serving",
        description="Concurrent federated training + hot-swap serving.")
    ap.add_argument("--preset", default="table2_quick",
                    help="repro.sim preset to train (default %(default)s)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--quick", action="store_true",
                    help="shrink the training run for CI smoke")
    ap.add_argument("--qps", type=float, default=50.0,
                    help="offered load (open loop; default %(default)s)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="server batch size (compile-once; default %(default)s)")
    ap.add_argument("--publish-dir", default=None,
                    help="checkpoint publish directory (default: a tempdir)")
    ap.add_argument("--publish-every", type=int, default=1,
                    help="publish every N rounds (default %(default)s)")
    ap.add_argument("--out", default=None,
                    help="write the repro.serve/v1 metrics JSON here")
    ap.add_argument("--settle-s", type=float, default=30.0,
                    help="max wait for the final checkpoint swap")
    ap.add_argument("--allow-no-swap", action="store_true",
                    help="exit 0 even when no hot swap happened")
    args = ap.parse_args(argv)

    import jax

    from repro import serving
    from repro.sim import Simulation, presets, publish_params_hook

    cfg = presets.get(args.preset)
    over: dict = {"ckpt_dir": None, "ckpt_every": 0, "out_json": None}
    if cfg.mode != "sync":
        print(f"error: preset {args.preset!r} is mode={cfg.mode!r}; the "
              "serve loop trains the sync engine", file=sys.stderr)
        return 2
    if args.rounds is not None:
        over["rounds"] = args.rounds
    if args.seed is not None:
        over["seed"] = args.seed
    if args.quick:
        over.setdefault("rounds", min(3, cfg.rounds))
        over["n_train"] = min(600, cfg.n_train)
        over["n_test"] = min(200, cfg.n_test)
        over["eval_every"] = 1
    cfg = cfg.replace(**over)

    publish_dir = args.publish_dir or tempfile.mkdtemp(prefix="repro_serve_")
    sim = Simulation(cfg)
    init_params = sim.model.init(jax.random.key(cfg.seed))

    metrics = serving.ServingMetrics(offered_qps=args.qps)
    buffers = serving.WeightBuffers(init_params, step=0)
    watcher = serving.CheckpointWatcher(publish_dir, init_params, buffers,
                                        metrics=metrics)
    server = serving.InferenceServer(
        serving.ClassifierAdapter(sim.model, args.max_batch),
        watcher=watcher, metrics=metrics)
    # request pool: the sim's own test split, cycled by request index
    import numpy as np

    payloads = np.asarray(sim.xt, np.float32)
    loadgen = serving.LoadGenerator(server, payloads, args.qps,
                                    metrics=metrics)

    print(f"# serve: preset={args.preset} rounds={cfg.rounds} "
          f"model={cfg.model} qps={args.qps:g} max_batch={args.max_batch} "
          f"publish_dir={publish_dir}", flush=True)
    t0 = time.perf_counter()
    watcher.start()
    server.start()
    loadgen.start()                      # open loop until stopped
    try:
        res = sim.run(resume=False,
                      hooks=[publish_params_hook(publish_dir,
                                                 every=args.publish_every)])
        # settle: serve until the final published checkpoint is active
        deadline = time.perf_counter() + args.settle_s
        target = cfg.rounds - (cfg.rounds % max(1, args.publish_every))
        while (buffers.active_step < target
               and time.perf_counter() < deadline):
            time.sleep(0.05)
    finally:
        loadgen.stop()                   # pace off + drain every in-flight
        server.stop()
        watcher.stop()
    metrics.wall_s = time.perf_counter() - t0

    doc = metrics.summary()
    errs = serving.validate_metrics(doc)
    if errs:
        print("metrics schema errors: " + "; ".join(errs), file=sys.stderr)
        return 1
    req, lat, sw = doc["requests"], doc["latency_us"], doc["swaps"]
    print(f"trained {cfg.rounds} rounds (final_acc={res.final_acc:.3f}) "
          f"while serving {req['served']} requests "
          f"({doc['qps']['sustained']:.1f}/s sustained, "
          f"{req['errors']} errors)")
    print(f"latency p50={lat['p50']:.0f}us p99={lat['p99']:.0f}us  "
          f"swaps={sw['count']} (pause p50={sw['pause_us']['p50']:.1f}us "
          f"max={sw['pause_us']['max']:.1f}us)  "
          f"staleness mean={doc['staleness']['mean']:.2f} "
          f"max={doc['staleness']['max']}")
    if args.out:
        metrics.to_json(args.out)
        print(f"metrics written to {args.out}")
    if req["errors"]:
        print(f"error: {req['errors']} dropped/errored request(s)",
              file=sys.stderr)
        return 1
    if not sw["count"] and not args.allow_no_swap:
        print("error: no hot swap happened (training published nothing the "
              "server picked up)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
