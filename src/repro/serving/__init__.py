"""repro.serving — continuous train -> checkpoint -> hot-swap serving.

The deployment leg of the north star (DESIGN.md §16): the sim engine
publishes checkpoints (``sim.publish_params_hook`` -> ``checkpoint.publish``,
atomic manifest-last), a batched jitted :class:`InferenceServer` picks them
up through a :class:`CheckpointWatcher` via double-buffered weight hot-swap
(``hot_swap.py`` — staging off the serve path, a pointer-flip swap between
batches), and a :class:`LoadGenerator` drives it open-loop at a configured
QPS while federated rounds keep training in the same process. Every run
renders one ``repro.serve/v1`` metrics document (``metrics.py``) that CI
asserts on and ``bench/serve_bench.py`` turns into BENCH_serve.json entries.

``python -m repro.serving`` runs the whole loop end to end.
"""
from __future__ import annotations

from repro.serving.hot_swap import CheckpointWatcher, WeightBuffers
from repro.serving.loadgen import LoadGenerator
from repro.serving.metrics import (SCHEMA_VERSION, ServingMetrics,
                                   load_metrics, validate_metrics)
from repro.serving.server import ClassifierAdapter, InferenceServer, LMAdapter

__all__ = [
    "CheckpointWatcher", "WeightBuffers", "LoadGenerator", "ServingMetrics",
    "SCHEMA_VERSION", "load_metrics", "validate_metrics",
    "ClassifierAdapter", "InferenceServer", "LMAdapter",
]
