"""The batched jitted inference server (DESIGN.md §16).

Control plane (host threads): a request queue, fixed-shape batch assembly,
the checkpoint watcher's swap hook between batches, per-request latency
accounting. Data plane (device): ONE jitted apply per adapter, compiled
once for the fixed ``[max_batch, ...]`` shape — partial batches are padded
(the pad rows are discarded on the host), so serving never re-traces, the
same compile-once contract the sim engine holds for training (DESIGN.md §9).

Two adapters cover the repo's workloads:

* :class:`ClassifierAdapter` — the federated credit-risk-shaped classifier
  (``models.paper_models``): request = one feature sample, response = its
  logits row.
* :class:`LMAdapter` — the batched prefill + greedy-decode path from
  ``launch/serve.py`` with donated KV-cache buffers: request = a fixed-length
  prompt, response = ``n_new`` generated tokens.

The server never blocks a request on training: weights change only via
``watcher.maybe_swap()`` between batches (hot_swap.py).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import (make_decode_step, make_prefill_step,
                                next_token)
from repro.serving.hot_swap import CheckpointWatcher, WeightBuffers
from repro.serving.metrics import ServingMetrics

PyTree = Any


# ------------------------------------------------------------------ adapters
class ClassifierAdapter:
    """Batched logits for a ``models.paper_models.PaperModel``."""

    request_dtype = np.float32

    def __init__(self, model, max_batch: int):
        self.model = model
        self.max_batch = int(max_batch)
        self.request_shape = tuple(model.input_shape)
        self._apply = jax.jit(model.apply)

    def infer(self, params: PyTree, stack: jax.Array) -> np.ndarray:
        """stack: [max_batch, *input_shape] -> np [max_batch, n_classes]."""
        out = self._apply(params, stack)
        return np.asarray(out.block_until_ready())

    def tokens_per_request(self) -> int:
        return 0


class LMAdapter:
    """Batched greedy generation with donated decode buffers.

    Requests are fixed-length int32 prompts (``prompt_len``); a batch runs
    one jitted prefill plus ``n_new - 1`` jitted decode steps whose KV-cache
    state is donated (``launch/serve.py``), so the cache updates in place.
    """

    request_dtype = np.int32

    def __init__(self, cfg, max_batch: int, prompt_len: int, n_new: int,
                 cache_len: Optional[int] = None):
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.prompt_len = int(prompt_len)
        self.n_new = int(n_new)
        self.cache_len = int(cache_len or (prompt_len + n_new + 8))
        self.request_shape = (self.prompt_len,)
        self._prefill = jax.jit(make_prefill_step(cfg, self.cache_len))
        self._step = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    def infer(self, params: PyTree, stack: jax.Array) -> np.ndarray:
        """stack: int32 [max_batch, prompt_len] -> np int32 [max_batch, n_new]."""
        logits, state = self._prefill(params, stack.astype(jnp.int32))
        tok = next_token(logits)
        out = [tok]
        for _ in range(self.n_new - 1):
            logits, state = self._step(params, tok, state)
            tok = next_token(logits)
            out.append(tok)
        gen = jnp.concatenate(out, axis=1)
        return np.asarray(gen.block_until_ready())

    def tokens_per_request(self) -> int:
        return self.n_new


# -------------------------------------------------------------------- server
class _Ticket:
    """One in-flight request: payload in, result/error out."""

    __slots__ = ("payload", "t_submit", "done", "result", "error")

    def __init__(self, payload: np.ndarray):
        self.payload = payload
        self.t_submit = time.perf_counter()
        self.done = threading.Event()
        self.result: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None

    def wait(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self.done.wait(timeout):
            raise TimeoutError("request not served in time")
        if self.error is not None:
            raise self.error
        return self.result


class InferenceServer:
    """Queue -> fixed-shape batch -> jitted apply -> per-request responses.

    Drive it synchronously with :meth:`step` (tests, benchmarks) or as a
    background thread with :meth:`start`/:meth:`stop` (loadgen, the
    train+serve CLI). ``watcher`` is optional — without one the server
    serves its initial weights forever.
    """

    def __init__(self, adapter, params: Optional[PyTree] = None, *,
                 step: int = 0,
                 watcher: Optional[CheckpointWatcher] = None,
                 metrics: Optional[ServingMetrics] = None,
                 batch_wait_s: float = 0.002):
        self.adapter = adapter
        if watcher is not None:
            self.buffers = watcher.buffers   # weights live with the watcher
        elif params is not None:
            self.buffers = WeightBuffers(params, step=step)
        else:
            raise ValueError("need initial params or a watcher")
        self.watcher = watcher
        self.metrics = metrics if metrics is not None else ServingMetrics()
        self.batch_wait_s = batch_wait_s
        self._queue: "queue.Queue[_Ticket]" = queue.Queue()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._zero = np.zeros(adapter.request_shape, adapter.request_dtype)

    # ------------------------------------------------------------ client side
    def submit(self, payload: np.ndarray) -> _Ticket:
        t = _Ticket(np.asarray(payload))
        self.metrics.record_submit()
        self._queue.put(t)
        return t

    # ------------------------------------------------------------ serve side
    def _collect(self, block: bool) -> list:
        """Up to ``max_batch`` queued tickets; with ``block`` waits
        ``batch_wait_s`` for the first one (micro-batching window)."""
        tickets = []
        try:
            tickets.append(self._queue.get(block=block,
                                           timeout=self.batch_wait_s))
        except queue.Empty:
            return tickets
        while len(tickets) < self.adapter.max_batch:
            try:
                tickets.append(self._queue.get_nowait())
            except queue.Empty:
                break
        return tickets

    def step(self, block: bool = False) -> int:
        """Serve one batch: swap if a fresh buffer is staged, assemble, run,
        respond. Returns the number of requests served."""
        if self.watcher is not None:
            self.watcher.maybe_swap()
        tickets = self._collect(block)
        if not tickets:
            return 0
        pad = self.adapter.max_batch - len(tickets)
        rows = [t.payload for t in tickets] + [self._zero] * pad
        stack = jnp.asarray(np.stack(rows))
        step_served = self.buffers.active_step
        latest = (self.watcher.latest_seen if self.watcher is not None
                  else None)
        self.metrics.record_batch(len(tickets), step_served, latest)
        try:
            out = self.adapter.infer(self.buffers.active_params, stack)
        except Exception as e:
            for t in tickets:
                t.error = e
                t.done.set()
                self.metrics.record_error()
            return len(tickets)
        now = time.perf_counter()
        toks = self.adapter.tokens_per_request()
        for i, t in enumerate(tickets):
            t.result = out[i]
            t.done.set()
            self.metrics.record_served((now - t.t_submit) * 1e6,
                                       step_served, tokens=toks)
        return len(tickets)

    def drain(self) -> int:
        """Serve until the queue is empty; returns requests served."""
        n = 0
        while True:
            served = self.step(block=False)
            if served == 0 and self._queue.empty():
                return n
            n += served

    # --------------------------------------------------------------- threading
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="inference-server", daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.step(block=True)
        self.drain()   # never strand an accepted request on shutdown

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=30.0)
            self._thread = None
        self.drain()
