"""Double-buffered weight hot-swap (DESIGN.md §16).

Two weight slots live on device. The *active* slot answers every request;
the *staging* slot is where a :class:`CheckpointWatcher` loads newly
published checkpoints — host-side npz read, ``device_put``, and a blocking
``block_until_ready`` all happen off the serve path (the watcher's loader
thread, or an explicit ``poll_once()``). The serve loop only ever calls
``maybe_swap()`` *between* batches: when a staged buffer is resident the
swap is a pointer flip under a lock — the measured pause is microseconds,
and a request never waits on a training round or a disk read. Old weights
keep serving until the instant the new buffer is complete.

Staleness invariant: ``active_step`` is monotone non-decreasing, and after
a failed/partial publish (npz without a parseable manifest —
``checkpoint.latest_published_step`` skips those) the server simply stays
on the last good step.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional

import jax

from repro import checkpoint

PyTree = Any


class WeightBuffers:
    """The two device-resident weight slots + the active pointer."""

    def __init__(self, params: PyTree, step: int = 0):
        params = jax.tree_util.tree_map(jax.numpy.asarray, params)
        self._slots: list[Optional[PyTree]] = [params, None]
        self._steps: list[int] = [int(step), -1]
        self._active = 0
        self._staged = False
        self._lock = threading.Lock()

    @property
    def active_params(self) -> PyTree:
        with self._lock:
            return self._slots[self._active]

    @property
    def active_step(self) -> int:
        with self._lock:
            return self._steps[self._active]

    @property
    def staged_step(self) -> Optional[int]:
        """Step resident in the staging slot, whether or not swapped yet."""
        with self._lock:
            s = self._steps[1 - self._active]
            return s if s >= 0 else None

    @property
    def has_staged(self) -> bool:
        with self._lock:
            return self._staged

    def stage(self, step: int, params: PyTree) -> None:
        """Load ``params`` into the inactive slot and mark it swappable.
        Blocks until the buffer is device-resident — callers keep this OFF
        the serve path."""
        params = jax.tree_util.tree_map(jax.numpy.asarray, params)
        for leaf in jax.tree_util.tree_leaves(params):
            leaf.block_until_ready()
        with self._lock:
            self._slots[1 - self._active] = params
            self._steps[1 - self._active] = int(step)
            self._staged = True

    def swap(self) -> float:
        """Flip the active pointer onto the staged slot; returns the pause
        in microseconds (the only instant the serve loop is 'down')."""
        t0 = time.perf_counter()
        with self._lock:
            if not self._staged:
                raise RuntimeError("swap() with nothing staged")
            self._active = 1 - self._active
            self._staged = False
        return (time.perf_counter() - t0) * 1e6


class CheckpointWatcher:
    """Polls a publish directory and stages new checkpoints for swapping.

    ``tree_of(step)`` defaults to ``checkpoint.restore`` against the
    ``like`` tree; only steps with a complete, parseable manifest are ever
    considered (``checkpoint.latest_published_step``), so a crash
    mid-publish leaves the watcher — and therefore the server — on the last
    good checkpoint.
    """

    def __init__(self, ckpt_dir: str, like: PyTree, buffers: WeightBuffers,
                 metrics=None,
                 restore_fn: Optional[Callable[[int], PyTree]] = None,
                 poll_interval_s: float = 0.05):
        self.ckpt_dir = ckpt_dir
        self.like = like
        self.buffers = buffers
        self.metrics = metrics
        self.poll_interval_s = poll_interval_s
        self._restore = restore_fn or self._restore_step
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.latest_seen: Optional[int] = None   # newest complete step found

    def _restore_step(self, step: int) -> PyTree:
        return checkpoint.restore(self.ckpt_dir, step, like=self.like)

    # ---------------------------------------------------------------- polling
    def poll_once(self) -> Optional[int]:
        """One poll: stage the newest complete step if it beats both the
        active and any already-staged step. Returns the staged step or None.
        Safe to call inline (tests) or from the loader thread."""
        newest = checkpoint.latest_published_step(self.ckpt_dir)
        if newest is None:
            return None
        self.latest_seen = newest
        horizon = max(self.buffers.active_step,
                      self.buffers.staged_step
                      if self.buffers.staged_step is not None else -1)
        if newest <= horizon:
            return None
        tree = self._restore(newest)
        self.buffers.stage(newest, tree)
        return newest

    def maybe_swap(self) -> Optional[int]:
        """Between-batches hook: flip onto a staged buffer when one is
        resident. Returns the new active step, or None if nothing swapped."""
        if not self.buffers.has_staged:
            return None
        pause_us = self.buffers.swap()
        step = self.buffers.active_step
        if self.metrics is not None:
            self.metrics.record_swap(step, pause_us)
        return step

    # ----------------------------------------------------------- loader thread
    def start(self) -> None:
        """Run the poll loop in a daemon loader thread (staging happens
        there; swapping stays with the serve loop)."""
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="ckpt-watcher", daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.poll_once()
            except (OSError, ValueError, KeyError):
                # a reader racing the publisher can lose (partial listing);
                # the next poll sees a consistent directory
                pass
            self._stop.wait(self.poll_interval_s)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
