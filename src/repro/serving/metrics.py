"""Serving metrics: the ``repro.serve/v1`` JSON document + its validator.

One :class:`ServingMetrics` instance rides along the whole serve loop
(server, hot-swap watcher, load generator all record into it, under one
lock) and renders to a single schema'd document that CI asserts on — the
same design as ``repro.bench.schema`` / ``repro.lint.report``: no jax
imports here, the validator must run without a backend.

Document shape::

    {
      "schema": "repro.serve/v1",
      "wall_s": 12.3,
      "requests": {"submitted": 400, "served": 400, "errors": 0},
      "latency_us": {"p50": 812.0, "p99": 4310.0, "mean": 990.1,
                     "max": 8120.4, "n": 400},
      "qps": {"offered": 50.0, "sustained": 49.2},
      "batches": {"count": 61, "mean_fill": 6.5},
      "swaps": {"count": 3, "pause_us": {"p50": 8.1, "max": 40.2},
                "steps": [1, 2, 3]},
      "staleness": {"mean": 0.21, "max": 1, "samples": 61},
      "checkpoints": {"served_steps": {"0": 120, "1": 160, "2": 120}},
      "tokens": {"generated": 0, "tok_s": 0.0}      # LM adapters only
    }

``staleness`` is measured at serve time, per batch: how many published
steps the weights answering this batch lag the newest complete checkpoint
(0 = serving the freshest model).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Optional

SCHEMA_VERSION = "repro.serve/v1"


def percentile(sorted_vals: list, q: float) -> float:
    """Nearest-rank percentile of an ascending list (0 <= q <= 100)."""
    if not sorted_vals:
        return float("nan")
    rank = max(0, min(len(sorted_vals) - 1,
                      int(round(q / 100.0 * (len(sorted_vals) - 1)))))
    return float(sorted_vals[rank])


class ServingMetrics:
    """Thread-safe accumulator for one serve run (see module docstring)."""

    def __init__(self, offered_qps: float = 0.0):
        self._lock = threading.Lock()
        self.offered_qps = float(offered_qps)
        self.submitted = 0
        self.served = 0
        self.errors = 0
        self.latencies_us: list[float] = []
        self.batch_fills: list[int] = []
        self.swap_pauses_us: list[float] = []
        self.swap_steps: list[int] = []
        self.staleness: list[int] = []
        self.served_by_step: dict[int, int] = {}
        self.tokens_generated = 0
        self.wall_s = 0.0

    # ------------------------------------------------------------- recording
    def record_submit(self) -> None:
        with self._lock:
            self.submitted += 1

    def record_served(self, latency_us: float, step: int,
                      tokens: int = 0) -> None:
        with self._lock:
            self.served += 1
            self.latencies_us.append(float(latency_us))
            self.served_by_step[int(step)] = \
                self.served_by_step.get(int(step), 0) + 1
            self.tokens_generated += int(tokens)

    def record_error(self) -> None:
        with self._lock:
            self.errors += 1

    def record_batch(self, fill: int, active_step: int,
                     latest_step: Optional[int]) -> None:
        with self._lock:
            self.batch_fills.append(int(fill))
            if latest_step is not None:
                self.staleness.append(max(0, int(latest_step) - int(active_step)))

    def record_swap(self, step: int, pause_us: float) -> None:
        with self._lock:
            self.swap_steps.append(int(step))
            self.swap_pauses_us.append(float(pause_us))

    # -------------------------------------------------------------- document
    def summary(self) -> dict:
        with self._lock:
            lats = sorted(self.latencies_us)
            pauses = sorted(self.swap_pauses_us)
            wall = max(self.wall_s, 1e-9)
            doc = {
                "schema": SCHEMA_VERSION,
                "wall_s": float(self.wall_s),
                "requests": {
                    "submitted": self.submitted,
                    "served": self.served,
                    "errors": self.errors,
                },
                "latency_us": {
                    "p50": percentile(lats, 50),
                    "p99": percentile(lats, 99),
                    "mean": (sum(lats) / len(lats)) if lats else float("nan"),
                    "max": lats[-1] if lats else float("nan"),
                    "n": len(lats),
                },
                "qps": {
                    "offered": self.offered_qps,
                    "sustained": self.served / wall,
                },
                "batches": {
                    "count": len(self.batch_fills),
                    "mean_fill": (sum(self.batch_fills) / len(self.batch_fills)
                                  if self.batch_fills else 0.0),
                },
                "swaps": {
                    "count": len(self.swap_steps),
                    "pause_us": {
                        "p50": percentile(pauses, 50),
                        "max": pauses[-1] if pauses else 0.0,
                    },
                    "steps": list(self.swap_steps),
                },
                "staleness": {
                    "mean": (sum(self.staleness) / len(self.staleness)
                             if self.staleness else 0.0),
                    "max": max(self.staleness) if self.staleness else 0,
                    "samples": len(self.staleness),
                },
                "checkpoints": {
                    "served_steps": {str(k): v for k, v in
                                     sorted(self.served_by_step.items())},
                },
                "tokens": {
                    "generated": self.tokens_generated,
                    "tok_s": self.tokens_generated / wall,
                },
            }
        return doc

    def to_json(self, path: str) -> str:
        doc = self.summary()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
        os.replace(tmp, path)
        return path


def validate_metrics(doc: dict) -> list[str]:
    """Schema errors ([] = valid); cross-checks the counts like
    ``repro.lint.report`` does (served + errors == submitted after a drained
    run, swap count == len(steps), latency n == served)."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return ["document is not an object"]
    if doc.get("schema") != SCHEMA_VERSION:
        errs.append(f"schema must be {SCHEMA_VERSION!r}, "
                    f"got {doc.get('schema')!r}")
    for key, fields in (
            ("requests", ("submitted", "served", "errors")),
            ("latency_us", ("p50", "p99", "mean", "max", "n")),
            ("qps", ("offered", "sustained")),
            ("batches", ("count", "mean_fill")),
            ("swaps", ("count", "pause_us", "steps")),
            ("staleness", ("mean", "max", "samples")),
            ("checkpoints", ("served_steps",)),
            ("tokens", ("generated", "tok_s")),
    ):
        block = doc.get(key)
        if not isinstance(block, dict):
            errs.append(f"missing {key!r} object")
            continue
        for f in fields:
            if f not in block:
                errs.append(f"{key}.{f} missing")
    if errs:
        return errs
    req = doc["requests"]
    for f in ("submitted", "served", "errors"):
        if not isinstance(req[f], int) or req[f] < 0:
            errs.append(f"requests.{f} must be an int >= 0")
    if not errs and req["served"] + req["errors"] != req["submitted"]:
        errs.append(
            f"counts do not reconcile: served {req['served']} + errors "
            f"{req['errors']} != submitted {req['submitted']} (undrained run?)")
    if doc["latency_us"]["n"] != req["served"]:
        errs.append(f"latency_us.n {doc['latency_us']['n']} != "
                    f"requests.served {req['served']}")
    sw = doc["swaps"]
    if not isinstance(sw["steps"], list) or sw["count"] != len(sw["steps"]):
        errs.append("swaps.count != len(swaps.steps)")
    served_sum = sum(doc["checkpoints"]["served_steps"].values())
    if served_sum != req["served"]:
        errs.append(f"checkpoints.served_steps sums to {served_sum} != "
                    f"requests.served {req['served']}")
    return errs


def load_metrics(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    errs = validate_metrics(doc)
    if errs:
        raise ValueError(f"{path}: invalid serve document: " + "; ".join(errs))
    return doc
