"""Pairwise encryption masks with sparse support (paper §3.2, Eq. 3-5).

Bonawitz-style secure aggregation: clients a<b agree — via an actual (toy-
parameter) Diffie-Hellman exchange over GF(2^61-1), see ``dh_agree`` — on a
common pair secret; each round both derive the SAME pseudo-random sparse
support S_ab and mask values m_ab, and client a adds +m_ab while b adds -m_ab,
so the server-side sum cancels exactly.

Sparse-mask adaptation (the paper's contribution): the mask is nonzero only on
``k_mask`` pseudo-random positions (expected fraction ``mask_ratio / x`` per
pair, matching Eq. 4's threshold sigma = p + (k/x) q on a uniform [p, p+q)
matrix). Both endpoints transmit every support position, so no mask is ever
left uncancelled — the failure mode of naive sparsify-then-mask that §2.2
analyses.

Masks are **counter-based** (murmur-avalanched uint32 streams keyed by the
pair seed — kernels/ref.py::pair_mask_stream_ref, Pallas twin in
kernels/mask_prng.py): regenerated on the fly each round, never stored. The
same draws power this host-side reference path, the batched engine
(core/streams.py) and the round protocol (repro/secagg/protocol.py), so
reference, engine and Shamir-reconstructed recovery masks are bit-identical.

This module is the *single-pair reference* — ``client_masks`` walks peers in
a host loop. The production data plane generates every pair of every client
in one fused pass (streams.encode_leaf_batch with ``pair_seeds``).
"""
from __future__ import annotations

import hashlib
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import SecureAggConfig
from repro.kernels import ref as kref

# Toy-parameter DH group: arithmetic is the real protocol's (modular
# exponentiation, shared secret g^(x_a x_b)), the parameters are NOT a secure
# choice — the threat-model boundary is documented in DESIGN.md §10.
DH_PRIME = (1 << 61) - 1   # Mersenne prime; also the Shamir field (secagg)
DH_GEN = 5


def dh_private(seed: int, u: int) -> int:
    """Client ``u``'s simulated DH private key in [1, DH_PRIME - 1).

    Derived from the federation seed so every party of the *simulation* can
    recompute it; a real deployment draws it from a CSPRNG. This is the
    secret that repro/secagg Shamir-shares for dropout recovery.
    """
    h = hashlib.sha256(f"dhpriv:{seed}:{u}".encode()).digest()
    return int.from_bytes(h[:16], "little") % (DH_PRIME - 2) + 1


def dh_public(x: int) -> int:
    """g^x mod p — the advertised public key of the key-agreement phase."""
    return pow(DH_GEN, x, DH_PRIME)


def dh_agree(seed: int, a: int, b: int) -> int:
    """Diffie-Hellman agreement -> shared pair secret g^(x_a x_b) (host-side).

    Both parties compute it independently (a from x_a and b's public key, b
    symmetrically); the server can recover it for a dropped client only via
    the Shamir shares of that client's private key (repro/secagg). The
    data-plane cost of the protocol — mask transmission — is what the
    framework models; DH itself is control-plane.
    """
    return pow(dh_public(dh_private(seed, b)), dh_private(seed, a), DH_PRIME)


def seed_from_secret(secret: int, round_t: int) -> int:
    """Per-round uint32 mask seed from a pair secret — no federation seed
    involved, so whoever holds the pair secret (both endpoints; the server
    after Shamir reconstruction) derives the identical counter stream."""
    h = hashlib.sha256(f"mask:{secret}:{round_t}".encode()).digest()
    return int.from_bytes(h[:4], "little")


def pair_seed(cfg: SecureAggConfig, a: int, b: int, round_t: int) -> int:
    """The round's uint32 counter seed for the unordered pair (a, b)."""
    return seed_from_secret(dh_agree(cfg.seed, a, b), round_t)


def seed_matrix_from_keys(ids: Sequence[int], privs: Sequence[int],
                          pubs: Sequence[int], round_t: int):
    """[C, C] uint32 pair-seed + f32 sign matrices from ordered key lists.

    ``seeds[i, j] = seed_from_secret(pubs[j] ** privs[i] mod p, round_t)`` —
    symmetric by DH, filled once per unordered pair. THE single derivation
    shared by the protocol-free engine entry (streams.pair_seed_matrix,
    which derives the keys from the federation seed), the round protocol's
    encode (RoundProtocol.pair_seed_matrix, from its stored key state) and
    the recovery replay (RoundProtocol.recover_seeds, from the Shamir-
    reconstructed key) — so encode and recovery masks cannot desynchronize.
    The diagonal (self pair) is seed 0 with sign 0; the encode value-gates
    its slots to zero and support-gates them onto the block's top-1 index.
    """
    n = len(ids)
    if not (len(privs) == len(pubs) == n):
        raise ValueError("ids, privs, pubs must be aligned")
    seeds = np.zeros((n, n), np.uint32)
    signs = np.zeros((n, n), np.float32)
    for i in range(n):
        for j in range(i + 1, n):
            secret = pow(pubs[j], privs[i], DH_PRIME)
            sd = seed_from_secret(secret, round_t)
            seeds[i, j] = seeds[j, i] = sd
            sgn = 1.0 if ids[i] < ids[j] else -1.0
            signs[i, j] = sgn
            signs[j, i] = -sgn
    return jnp.asarray(seeds), jnp.asarray(signs)


def pair_key(cfg: SecureAggConfig, a: int, b: int, round_t: int) -> jax.Array:
    """Legacy jax.random pair key (dense Bonawitz baseline + blocked path)."""
    secret = dh_agree(cfg.seed, a, b)
    key = jax.random.key(secret % (2**31 - 1))
    return jax.random.fold_in(key, round_t)


class PairMask(NamedTuple):
    """One pair's sparse mask: ``k_mask`` (index, signed value) slots.

    ``indices`` are flat positions and MAY repeat (mod-size collisions of the
    counter stream). Duplicates are part of the contract, not a bug: both
    endpoints generate identical duplicates (each slot cancels against its
    twin), and the unified-stream encode transmits the underlying *gradient*
    value only at a slot's first occurrence (streams.first_occurrence_rows),
    so a double-hit position is never double-counted — pinned end-to-end by
    tests/test_secagg_protocol.py::test_duplicate_support_not_double_counted.
    """

    indices: jax.Array  # int32[k_mask] support positions (flat, may repeat)
    values: jax.Array   # float32[k_mask] signed mask values in +-[p, p+q)


def pair_mask(
    cfg: SecureAggConfig,
    a: int,
    b: int,
    round_t: int,
    leaf_id: int,
    size: int,
    k_mask: int,
) -> PairMask:
    """Mask of client ``a`` towards client ``b`` for one leaf, one round.

    Deterministic in (unordered pair, round, leaf): both endpoints generate
    identical (indices, |values|); the endpoint with the smaller id adds
    +values, the other -values (Bonawitz sign convention), so sums cancel.
    Counter-based draws — bit-identical to the batched engine and the Pallas
    kernel (kernels/mask_prng.py::pair_mask_streams).
    """
    seed = kref.fold_leaf_seed(
        jnp.uint32(pair_seed(cfg, a, b, round_t)), leaf_id)
    sign = 1.0 if a < b else -1.0
    idx, vals = kref.pair_mask_stream_ref(
        seed, jnp.float32(sign), 1, k_mask, size, p=cfg.p, q=cfg.q)
    return PairMask(indices=idx[0], values=vals[0])


def client_masks(
    cfg: SecureAggConfig,
    client: int,
    others: Sequence[int],
    round_t: int,
    leaf_id: int,
    size: int,
    k_mask: int,
) -> PairMask:
    """Concatenated masks of ``client`` towards every other participant.

    Protocol-reference host loop over peers; the batched data plane
    (streams.encode_leaf_batch with ``pair_seeds``) produces the same slots
    for all clients in one fused pass.
    """
    parts = [
        pair_mask(cfg, client, b, round_t, leaf_id, size, k_mask)
        for b in others
        if b != client
    ]
    if not parts:
        z = jnp.zeros((0,), jnp.int32)
        return PairMask(indices=z, values=jnp.zeros((0,), jnp.float32))
    return PairMask(
        indices=jnp.concatenate([p.indices for p in parts]),
        values=jnp.concatenate([p.values for p in parts]),
    )
