"""Pairwise encryption masks with sparse support (paper §3.2, Eq. 3-5).

Bonawitz-style secure aggregation: clients a<b agree (via a DH exchange, which is
control-plane and simulated host-side by ``dh_agree``) on a common seed; each round
both derive the SAME pseudo-random sparse support S_ab and mask values m_ab, and
client a adds +m_ab while b adds -m_ab, so the server-side sum cancels exactly.

Sparse-mask adaptation (the paper's contribution): the mask is nonzero only on
``k_mask`` pseudo-random positions (expected fraction ``mask_ratio / x`` per pair,
matching Eq. 4's threshold sigma = p + (k/x) q on a uniform [p, p+q) matrix). Both
endpoints transmit every support position, so no mask is ever left uncancelled —
the failure mode of naive sparsify-then-mask that §2.2 analyses.

Masks are counter-based (jax.random.fold_in chains): regenerated on the fly each
round, never stored, which is also how the TPU kernel variant works.
"""
from __future__ import annotations

import hashlib
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.types import SecureAggConfig


class PairMask(NamedTuple):
    indices: jax.Array  # int32[k_mask] support positions (flat, may repeat)
    values: jax.Array   # float32[k_mask] signed mask values in +-[p, p+q)


def dh_agree(seed: int, a: int, b: int) -> int:
    """Simulated Diffie-Hellman agreement -> shared pair secret (host-side).

    Stands in for the DH exchange of the secure-aggregation protocol; both parties
    can compute it independently (here: a keyed hash of the unordered pair).
    The data-plane cost of the protocol — mask transmission — is what the
    framework models; DH itself is a once-per-federation control-plane exchange.
    """
    lo, hi = (a, b) if a < b else (b, a)
    h = hashlib.sha256(f"{seed}:{lo}:{hi}".encode()).digest()
    return int.from_bytes(h[:8], "little")


def pair_key(cfg: SecureAggConfig, a: int, b: int, round_t: int) -> jax.Array:
    secret = dh_agree(cfg.seed, a, b)
    key = jax.random.key(secret % (2**31 - 1))
    return jax.random.fold_in(key, round_t)


def pair_mask(
    cfg: SecureAggConfig,
    a: int,
    b: int,
    round_t: int,
    leaf_id: int,
    size: int,
    k_mask: int,
) -> PairMask:
    """Mask of client ``a`` towards client ``b`` for one leaf, one round.

    Deterministic in (unordered pair, round, leaf): both endpoints generate
    identical (indices, |values|); the endpoint with the smaller id adds +values,
    the other -values (Bonawitz sign convention), so sums cancel.
    """
    key = jax.random.fold_in(pair_key(cfg, a, b, round_t), leaf_id)
    k_idx, k_val = jax.random.split(key)
    idx = jax.random.randint(k_idx, (k_mask,), 0, size, dtype=jnp.int32)
    mag = jax.random.uniform(
        k_val, (k_mask,), minval=cfg.p, maxval=cfg.p + cfg.q, dtype=jnp.float32
    )
    sign = 1.0 if a < b else -1.0
    return PairMask(indices=idx, values=sign * mag)


def client_masks(
    cfg: SecureAggConfig,
    client: int,
    others: Sequence[int],
    round_t: int,
    leaf_id: int,
    size: int,
    k_mask: int,
) -> PairMask:
    """Concatenated masks of ``client`` towards every other participant."""
    parts = [
        pair_mask(cfg, client, b, round_t, leaf_id, size, k_mask)
        for b in others
        if b != client
    ]
    if not parts:
        z = jnp.zeros((0,), jnp.int32)
        return PairMask(indices=z, values=jnp.zeros((0,), jnp.float32))
    return PairMask(
        indices=jnp.concatenate([p.indices for p in parts]),
        values=jnp.concatenate([p.values for p in parts]),
    )
