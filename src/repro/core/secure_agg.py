"""Secure aggregation with sparse encryption masks (paper Alg. 2, Eq. 5).

The XLA-native realization of ``G_sparse = encode((G + mask_e) ⊙ mask_t)`` with
``mask_t = topk(|acc|) ∪ support(mask_e)`` is a static-shape *unified stream* per
leaf and client:

    idx   = concat(topk_idx, mask_support_idx)           # static k + (x-1)*k_mask
    vals  = acc[idx] * first_occurrence(idx) + mask_vals # dedup double-hits
    resid = acc.at[idx].set(0)                           # Alg.2 line 17

Scatter-adding every client's (idx, vals) on the server reproduces
``sum_clients acc ⊙ mask_t`` exactly: the gradient contribution of an index that
appears in several slots is counted once (first-occurrence gate), and the pairwise
mask values cancel because both endpoints of each pair transmit the same support
(see core/masks.py). This is the property tests/test_secure_agg.py verifies.

This module is the *protocol-reference, single-client* API. The encode/decode
implementation lives in the unified stream engine (core/streams.py, DESIGN.md
§3), which also provides the batched/jitted entries the server loop
(core/fedavg.py) and the datacenter steps (launch/train.py) use.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import streams as se
from repro.core.masks import PairMask, client_masks
from repro.core.types import SecureAggConfig, SparseStream, THGSConfig


class EncodedLeaf(NamedTuple):
    stream: SparseStream
    residual: jax.Array


def encode_leaf(
    grad: jax.Array,
    residual: jax.Array,
    k: int,
    thgs: THGSConfig,
    mask: PairMask | None,
) -> EncodedLeaf:
    """Error-feedback accumulate -> top-k ∪ mask support -> unified stream.

    Protocol-reference single-client entry: the mask support arrives as an
    explicit ``PairMask`` (host-derived via masks.client_masks / dh_agree);
    the encode itself is the engine's single implementation
    (streams.unified_stream_rows) on the 1-block view.
    """
    acc = (residual + grad).astype(jnp.float32)
    flat = acc.reshape(-1)[None, :]          # [nb=1, m=size] block view
    n = flat.shape[1]
    k = int(min(k, n))
    if mask is not None and mask.indices.shape[0] > 0:
        m_idx = mask.indices[None, :]
        m_vals = mask.values[None, :]
    else:
        m_idx = m_vals = None
    idx, vals, new_acc = se.unified_stream_rows(
        flat, k, m_idx, m_vals, selector=thgs.selector,
        sample_frac=thgs.sample_frac)
    return EncodedLeaf(
        stream=SparseStream(indices=idx[0], values=vals[0]),
        residual=new_acc[0].reshape(acc.shape).astype(residual.dtype),
    )


def encode_update(
    update: dict | list,
    residuals: dict | list,
    ks: Sequence[int],
    thgs: THGSConfig,
    sa: SecureAggConfig,
    client: int,
    participants: Sequence[int],
    round_t: int,
):
    """Encode a whole pytree update. Returns (streams, new_residuals)."""
    leaves, treedef = jax.tree_util.tree_flatten(update)
    res_leaves = jax.tree_util.tree_leaves(residuals)
    assert len(leaves) == len(res_leaves) == len(ks)
    streams, new_res = [], []
    for leaf_id, (g, r, k) in enumerate(zip(leaves, res_leaves, ks)):
        if sa.enabled and len(participants) >= 2:
            k_mask = sa.k_mask_for(g.size, len(participants))
            mask = client_masks(
                sa, client, participants, round_t, leaf_id, g.size, k_mask
            )
        else:
            mask = None
        enc = encode_leaf(g, r, k, thgs, mask)
        streams.append(enc.stream)
        new_res.append(enc.residual)
    return streams, jax.tree_util.tree_unflatten(treedef, new_res)


def aggregate_streams(
    client_streams: Sequence[Sequence[SparseStream]],
    leaf_shapes: Sequence[tuple],
    leaf_dtypes: Sequence,
    weights: Sequence[float] | None = None,
) -> list[jax.Array]:
    """Server-side decode+sum: one fused scatter-add over all clients per leaf.

    Pairwise masks cancel in the sum; the result equals
    ``sum_c w_c * (acc_c ⊙ mask_t_c)`` reshaped to the leaf shapes. Ragged
    per-client stream lengths are zero-padded (index 0, value 0 — a no-op
    under scatter-add) so the whole round decodes through the engine's single
    fused pass (streams.decode_sum_blocks). NOTE: ``weights`` here are applied
    server-side to the full values (masks included) — exact only when uniform;
    heterogeneous weighting belongs client-side in the encode (see
    core/streams.py).
    """
    n_clients = len(client_streams)
    if weights is None:
        weights = [1.0 / n_clients] * n_clients
    w = jnp.asarray(weights, jnp.float32)
    out = []
    for leaf_id, shape in enumerate(leaf_shapes):
        size = 1
        for d in shape:
            size *= d
        k_max = max(client_streams[c][leaf_id].k for c in range(n_clients))
        idx = jnp.stack([
            jnp.pad(client_streams[c][leaf_id].indices,
                    (0, k_max - client_streams[c][leaf_id].k))
            for c in range(n_clients)])[:, None, :]
        vals = jnp.stack([
            jnp.pad(client_streams[c][leaf_id].values.astype(jnp.float32),
                    (0, k_max - client_streams[c][leaf_id].k))
            for c in range(n_clients)])[:, None, :]
        dense = se.decode_sum_blocks(
            se.StreamBatch(indices=idx, values=vals), 1, size, weights=w)
        out.append(dense.reshape(shape).astype(leaf_dtypes[leaf_id]))
    return out


def dense_masked_update(
    update_leaf: jax.Array,
    sa: SecureAggConfig,
    client: int,
    participants: Sequence[int],
    round_t: int,
    leaf_id: int,
) -> jax.Array:
    """Classic (non-sparse) Bonawitz masking of a dense update — the SA baseline.

    Full-size pairwise masks added to the dense update; aggregation is a plain
    sum/psum and transmits every element (the communication cost the paper's
    sparse-mask method removes).
    """
    from repro.core.masks import pair_key

    flat = update_leaf.reshape(-1).astype(jnp.float32)
    for b in participants:
        if b == client:
            continue
        key = jax.random.fold_in(pair_key(sa, client, b, round_t), leaf_id)
        mag = jax.random.uniform(
            key, flat.shape, minval=sa.p, maxval=sa.p + sa.q, dtype=jnp.float32
        )
        flat = flat + (1.0 if client < b else -1.0) * mag
    return flat.reshape(update_leaf.shape)
