"""Block-local THGS encode for the datacenter mesh (jit-native, static shapes).

The single-host path (core/secure_agg.py) does exact per-leaf top-k; at 10^9+
parameters sharded over 256 devices a global top-k is a giant sort collective.
The production path splits each leaf into ``n_blocks`` contiguous blocks
(aligned with the device layout) and runs the identical encode *per block* —
the standard distributed adaptation of layer-wise top-k (DGC/STC, DESIGN.md §4).

Every helper here is shape-static and differentiation-free; it runs inside the
pjit/shard_map train step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class BlockedStream(NamedTuple):
    indices: jax.Array   # int32[n_blocks, k_total] — global flat indices
    values: jax.Array    # f32[n_blocks, k_total]


def _first_occurrence_rows(idx: jax.Array) -> jax.Array:
    """Per-row first-occurrence mask for [n_blocks, k] index rows."""
    order = jnp.argsort(idx, axis=-1)
    sorted_idx = jnp.take_along_axis(idx, order, -1)
    is_first = jnp.concatenate(
        [jnp.ones_like(sorted_idx[:, :1], bool),
         sorted_idx[:, 1:] != sorted_idx[:, :-1]], -1)
    out = jnp.zeros_like(is_first)
    return out.at[jnp.arange(idx.shape[0])[:, None], order].set(is_first)


def block_layout(size: int, n_blocks: int) -> tuple[int, int, int]:
    """(n_blocks, block_len, padded) — small leaves collapse to one block."""
    if size < 4 * n_blocks:
        n_blocks = 1
    m = -(-size // n_blocks)
    return n_blocks, m, n_blocks * m


def sharding_aligned_transform(shape, pspec, axis_sizes: dict,
                               intra_order: tuple):
    """Zero-communication blocked view of a sharded leaf.

    Splits each dim that PartitionSpec shards into (axis_size, dim/axis_size),
    moves the axis-sized dims to the front (in ``intra_order``), and flattens —
    so block i is exactly device i's shard, and the reshape/transpose never
    moves data. Forcing an arbitrary row-block layout instead costs two
    param-sized all-to-alls per step (measured: +25 GiB collectives on yi-6b).

    Returns (to_blocks, from_blocks, n_blocks, m, front_axes) or None when the
    spec has multi-axis entries (caller falls back to the generic layout).
    """
    import numpy as _np

    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    split_shape, perm_front, rest_positions = [], {}, []
    pos = 0
    for d, ax in zip(shape, spec):
        if ax is None:
            split_shape.append(d)
            rest_positions.append(pos)
            pos += 1
        elif isinstance(ax, str) and ax in axis_sizes and d % axis_sizes[ax] == 0:
            n = axis_sizes[ax]
            split_shape += [n, d // n]
            perm_front[ax] = pos
            rest_positions.append(pos + 1)
            pos += 2
        else:
            return None  # tuple-axis or non-divisible: generic fallback
    front = [perm_front[a] for a in intra_order if a in perm_front]
    if not front:
        return None  # fully replicated leaf
    perm = front + rest_positions
    n_blocks = 1
    for a in intra_order:
        if a in perm_front:
            n_blocks *= axis_sizes[a]
    m = int(_np.prod([split_shape[i] for i in rest_positions])) if rest_positions else 1
    inv_perm = _np.argsort(perm).tolist()

    def to_blocks(x):
        return x.reshape(split_shape).transpose(perm).reshape(n_blocks, m)

    def from_blocks(b):
        mid = [split_shape[i] for i in perm]
        return b.reshape(mid).transpose(inv_perm).reshape(shape)

    front_axes = tuple(a for a in intra_order if a in perm_front)
    return to_blocks, from_blocks, n_blocks, m, front_axes


def encode_leaf_blocked(
    g: jax.Array,
    residual: jax.Array,
    k_block: int,
    n_blocks: int,
    *,
    mask_key: jax.Array | None = None,
    k_mask_block: int = 0,
    n_peers: int = 0,
    self_id: jax.Array | None = None,
    mask_lo: float = -1.0,
    mask_q: float = 2.0,
    block_sharding=None,  # NamedSharding for the [n_blocks, m] view; blocks
                          # align with devices so every op below is shard-local
    transform=None,       # (to_blocks, from_blocks, n_blocks, m) from
                          # sharding_aligned_transform: zero-comm block view
) -> tuple[BlockedStream, jax.Array]:
    """Error-feedback accumulate -> block-local top-k (∪ pairwise mask support).

    When mask args are given, pairwise masks are generated counter-based per
    (unordered pair, leaf, block): peer j in [0, n_peers) != self contributes
    support indices and signed uniform values exactly as core/masks.py, so the
    cross-participant sum cancels. Returns (stream, new_residual).
    """
    size = g.size
    if transform is not None:
        to_blocks, from_blocks, n_blocks, m = transform[:4]
    else:
        n_blocks, m, padded = block_layout(size, n_blocks)

        def to_blocks(x):
            # keep the narrow dtype through the reshape boundary and constrain
            # the block view immediately — a replicated f32 flat copy of a
            # multi-GiB leaf otherwise materializes before the constraint
            flat = jnp.pad(x.reshape(-1), (0, padded - size))
            b = flat.reshape(n_blocks, m)
            if block_sharding is not None and n_blocks > 1:
                b = jax.lax.with_sharding_constraint(b, block_sharding)
            return b

        from_blocks = None
    k_block = int(min(k_block, m))

    blocks = (to_blocks(residual).astype(jnp.float32)
              + to_blocks(g).astype(jnp.float32))
    if block_sharding is not None and n_blocks > 1 and transform is None:
        blocks = jax.lax.with_sharding_constraint(blocks, block_sharding)

    top_abs, idx_t = jax.lax.top_k(jnp.abs(blocks), k_block)   # [nb, kb]

    if mask_key is not None and k_mask_block > 0 and n_peers >= 2:
        pair_idx_list, pair_val_list = [], []
        for peer in range(n_peers):
            # unordered pair id; self==peer contributes zeros (masked out below)
            lo = jnp.minimum(self_id, peer)
            hi = jnp.maximum(self_id, peer)
            pk = jax.random.fold_in(jax.random.fold_in(mask_key, lo), hi)
            k_i, k_v = jax.random.split(pk)
            pidx = jax.random.randint(k_i, (n_blocks, k_mask_block), 0, m,
                                      dtype=jnp.int32)
            pval = jax.random.uniform(k_v, (n_blocks, k_mask_block),
                                      minval=mask_lo, maxval=mask_lo + mask_q)
            sign = jnp.where(self_id < peer, 1.0, -1.0)
            active = (self_id != peer).astype(jnp.float32)
            pair_idx_list.append(pidx)
            pair_val_list.append(sign * active * pval)
        idx_m = jnp.concatenate(pair_idx_list, -1)
        val_m = jnp.concatenate(pair_val_list, -1)
        idx = jnp.concatenate([idx_t, idx_m], -1)
        mask_vals = jnp.concatenate(
            [jnp.zeros_like(top_abs), val_m], -1)
    else:
        idx = idx_t
        mask_vals = jnp.zeros_like(top_abs)

    first = _first_occurrence_rows(idx)
    gvals = jnp.take_along_axis(blocks, idx, -1)
    vals = gvals * first.astype(blocks.dtype) + mask_vals

    rows = jnp.arange(n_blocks)[:, None]
    new_blocks = blocks.at[rows, idx].set(0.0)
    if transform is not None:
        new_resid = from_blocks(new_blocks)
    else:
        new_resid = new_blocks.reshape(-1)[:size].reshape(g.shape)

    global_idx = (rows * m + idx).astype(jnp.int32)
    return BlockedStream(indices=global_idx, values=vals), new_resid.astype(
        residual.dtype)


def decode_blocked_sum(streams_idx: jax.Array, streams_vals: jax.Array,
                       size: int, n_blocks: int, weight: float,
                       block_sharding=None, transform=None) -> jax.Array:
    """Scatter-add gathered streams [n_fed, nb, k] into a dense flat leaf.

    The dense buffer is kept in its [n_blocks, m] device-aligned layout while
    scattering (a flat replicated f32 buffer of a multi-GiB leaf per device is
    what this avoids); the caller reshapes/re-constrains to the leaf layout.
    """
    if transform is not None:
        from_blocks, nb, m = transform[1], transform[2], transform[3]
    else:
        nb, m, padded = block_layout(size, n_blocks)
        from_blocks = None
    dense = jnp.zeros((nb, m), jnp.float32)
    if block_sharding is not None and nb > 1:
        dense = jax.lax.with_sharding_constraint(dense, block_sharding)
    flat_idx = streams_idx.reshape(-1)
    dense = dense.at[flat_idx // m, flat_idx % m].add(
        weight * streams_vals.reshape(-1))
    if block_sharding is not None and nb > 1:
        dense = jax.lax.with_sharding_constraint(dense, block_sharding)
    if transform is not None:
        return from_blocks(dense)  # leaf-shaped, zero-comm layout inverse
    return dense.reshape(-1)[:size]
