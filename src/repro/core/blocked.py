"""Block layouts for the datacenter mesh — views, not encode logic.

The single-host path does exact per-leaf top-k; at 10^9+ parameters sharded
over 256 devices a global top-k is a giant sort collective. The production
path splits each leaf into ``n_blocks`` contiguous blocks (aligned with the
device layout) and runs the identical encode *per block* — the standard
distributed adaptation of layer-wise top-k (DGC/STC, DESIGN.md §4).

Since the stream-engine refactor (DESIGN.md §3) this module owns only the
*layout* machinery: ``block_layout`` (generic padded row blocks) and
``sharding_aligned_transform`` (the zero-communication device-aligned view).
The encode/decode themselves are thin delegations to the one implementation
in core/streams.py — ``encode_leaf_blocked``/``decode_blocked_sum`` are kept
as the sharding-aware entry points the shard_map train step calls.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import streams as se
from repro.core.streams import block_layout


class BlockedStream(NamedTuple):
    indices: jax.Array   # int32[n_blocks, k_total] — global flat indices
    values: jax.Array    # f32[n_blocks, k_total]


def sharding_aligned_transform(shape, pspec, axis_sizes: dict,
                               intra_order: tuple):
    """Zero-communication blocked view of a sharded leaf.

    Splits each dim that PartitionSpec shards into (axis_size, dim/axis_size),
    moves the axis-sized dims to the front (in ``intra_order``), and flattens —
    so block i is exactly device i's shard, and the reshape/transpose never
    moves data. Forcing an arbitrary row-block layout instead costs two
    param-sized all-to-alls per step (measured: +25 GiB collectives on yi-6b).

    Returns (to_blocks, from_blocks, n_blocks, m, front_axes) or None when the
    spec has multi-axis entries (caller falls back to the generic layout).
    """
    import numpy as _np

    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    split_shape, perm_front, rest_positions = [], {}, []
    pos = 0
    for d, ax in zip(shape, spec):
        if ax is None:
            split_shape.append(d)
            rest_positions.append(pos)
            pos += 1
        elif isinstance(ax, str) and ax in axis_sizes and d % axis_sizes[ax] == 0:
            n = axis_sizes[ax]
            split_shape += [n, d // n]
            perm_front[ax] = pos
            rest_positions.append(pos + 1)
            pos += 2
        else:
            return None  # tuple-axis or non-divisible: generic fallback
    front = [perm_front[a] for a in intra_order if a in perm_front]
    if not front:
        return None  # fully replicated leaf
    perm = front + rest_positions
    n_blocks = 1
    for a in intra_order:
        if a in perm_front:
            n_blocks *= axis_sizes[a]
    m = int(_np.prod([split_shape[i] for i in rest_positions])) if rest_positions else 1
    inv_perm = _np.argsort(perm).tolist()

    def to_blocks(x):
        return x.reshape(split_shape).transpose(perm).reshape(n_blocks, m)

    def from_blocks(b):
        mid = [split_shape[i] for i in perm]
        return b.reshape(mid).transpose(inv_perm).reshape(shape)

    front_axes = tuple(a for a in intra_order if a in perm_front)
    return to_blocks, from_blocks, n_blocks, m, front_axes


def encode_leaf_blocked(
    g: jax.Array,
    residual: jax.Array,
    k_block: int,
    n_blocks: int,
    *,
    mask_key: jax.Array | None = None,
    k_mask_block: int = 0,
    n_peers: int = 0,
    self_id: jax.Array | None = None,
    mask_lo: float = -1.0,
    mask_q: float = 2.0,
    block_sharding=None,  # NamedSharding for the [n_blocks, m] view; blocks
                          # align with devices so every op below is shard-local
    transform=None,       # (to_blocks, from_blocks, n_blocks, m) from
                          # sharding_aligned_transform: zero-comm block view
) -> tuple[BlockedStream, jax.Array]:
    """Error-feedback accumulate -> block-local top-k (∪ pairwise mask support).

    Sharding-aware wrapper over the engine's single encode
    (streams.encode_client_blocks): this function owns the block view and the
    sharding constraints; the top-k ∪ mask-support unified stream itself lives
    in core/streams.py. When mask args are given, pairwise masks are generated
    counter-based per (unordered pair, block): peer j in [0, n_peers) != self
    contributes support indices and signed uniform values exactly as
    core/masks.py, so the cross-participant sum cancels.
    Returns (stream, new_residual).
    """
    size = g.size
    if transform is not None:
        to_blocks, from_blocks, n_blocks, m = transform[:4]
    else:
        n_blocks, m, padded = block_layout(size, n_blocks)

        def to_blocks(x):
            # keep the narrow dtype through the reshape boundary and constrain
            # the block view immediately — a replicated f32 flat copy of a
            # multi-GiB leaf otherwise materializes before the constraint
            flat = jnp.pad(x.reshape(-1), (0, padded - size))
            b = flat.reshape(n_blocks, m)
            if block_sharding is not None and n_blocks > 1:
                b = jax.lax.with_sharding_constraint(b, block_sharding)
            return b

        from_blocks = None
    k_block = int(min(k_block, m))

    blocks = (to_blocks(residual).astype(jnp.float32)
              + to_blocks(g).astype(jnp.float32))
    if block_sharding is not None and n_blocks > 1 and transform is None:
        blocks = jax.lax.with_sharding_constraint(blocks, block_sharding)

    if mask_key is not None and k_mask_block > 0 and n_peers >= 2:
        keys_row, signs_row = se.fold_pair_keys_row(mask_key, self_id, n_peers)
    else:
        keys_row = signs_row = None
        k_mask_block = 0

    global_idx, vals, new_blocks = se.encode_client_blocks(
        blocks, k_block,
        pair_keys_row=keys_row, pair_signs_row=signs_row,
        k_mask=k_mask_block, mask_p=mask_lo, mask_q=mask_q)

    if transform is not None:
        new_resid = from_blocks(new_blocks)
    else:
        new_resid = new_blocks.reshape(-1)[:size].reshape(g.shape)

    return BlockedStream(indices=global_idx, values=vals), new_resid.astype(
        residual.dtype)


def decode_blocked_sum(streams_idx: jax.Array, streams_vals: jax.Array,
                       size: int, n_blocks: int, weight: float,
                       block_sharding=None, transform=None) -> jax.Array:
    """Scatter-add gathered streams [n_fed, nb, k] into a dense flat leaf.

    The GSPMD-sharded counterpart of streams.decode_sum_blocks: the dense
    buffer is kept in its [n_blocks, m] device-aligned layout while scattering
    (a flat replicated f32 buffer of a multi-GiB leaf per device is what this
    avoids); the caller reshapes/re-constrains to the leaf layout.
    """
    if transform is not None:
        from_blocks, nb, m = transform[1], transform[2], transform[3]
    else:
        nb, m, padded = block_layout(size, n_blocks)
        from_blocks = None
    dense = jnp.zeros((nb, m), jnp.float32)
    if block_sharding is not None and nb > 1:
        dense = jax.lax.with_sharding_constraint(dense, block_sharding)
    flat_idx = streams_idx.reshape(-1)
    dense = dense.at[flat_idx // m, flat_idx % m].add(
        weight * streams_vals.reshape(-1))
    if block_sharding is not None and nb > 1:
        dense = jax.lax.with_sharding_constraint(dense, block_sharding)
    if transform is not None:
        return from_blocks(dense)  # leaf-shaped, zero-comm layout inverse
    return dense.reshape(-1)[:size]
