"""Core: THGS sparsification + sparse-mask secure aggregation (the paper's contribution)."""
from repro.core.types import (
    CommRecord,
    FedConfig,
    SecureAggConfig,
    SparseStream,
    THGSConfig,
    tree_size,
    tree_zeros_like,
)
from repro.core.schedules import layer_rates, leaf_ks, round_rate
from repro.core.sparsify import densify, first_occurrence_mask, member_of, sparsify_leaf
from repro.core.masks import (client_masks, dh_agree, dh_private, dh_public,
                              pair_mask, pair_seed)
from repro.core.secure_agg import (
    aggregate_streams,
    dense_masked_update,
    encode_leaf,
    encode_update,
)
from repro.core.fedavg import (FederatedState, batched_client_update,
                               client_update, init_state, run_round)
from repro.core import costs
from repro.core import streams
from repro.core.streams import (StreamBatch, decode_leaf_batch,
                                dropout_cancel_streams,
                                dropout_cancel_streams_seeded,
                                encode_leaf_batch, mask_streams_all_pairs,
                                pair_key_matrix, pair_seed_matrix)
from repro.core.blocked import (BlockedStream, decode_blocked_sum,
                                encode_leaf_blocked,
                                sharding_aligned_transform)

__all__ = [
    "CommRecord", "FedConfig", "SecureAggConfig", "SparseStream", "THGSConfig",
    "tree_size", "tree_zeros_like", "layer_rates", "leaf_ks", "round_rate",
    "densify", "first_occurrence_mask", "member_of", "sparsify_leaf",
    "client_masks", "dh_agree", "dh_private", "dh_public", "pair_mask",
    "pair_seed", "aggregate_streams",
    "dense_masked_update", "encode_leaf", "encode_update",
    "FederatedState", "batched_client_update", "client_update", "init_state",
    "run_round", "costs", "streams", "StreamBatch", "decode_leaf_batch",
    "dropout_cancel_streams", "dropout_cancel_streams_seeded",
    "encode_leaf_batch", "mask_streams_all_pairs", "pair_key_matrix",
    "pair_seed_matrix",
    "BlockedStream", "decode_blocked_sum", "encode_leaf_blocked",
    "sharding_aligned_transform",
]
