"""Core datatypes for the THGS + sparse-secure-aggregation framework.

Shapes are always static under jit: every stream size (``k`` for top-k, ``k_mask``
per pair) is a Python int decided host-side from the sparsity schedules before the
step function is traced.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseStream:
    """Static-shape sparse encoding of one tensor (one THGS layer/leaf).

    ``indices`` index into the *flattened* tensor; ``values`` carry
    ``acc[idx] * first_occurrence + mask`` per slot (see core/streams.py).
    Duplicate indices are allowed; scatter-add semantics resolve them.
    """

    indices: jax.Array  # int32[k_total]
    values: jax.Array   # float[k_total]

    @property
    def k(self) -> int:
        return self.indices.shape[-1]


@dataclasses.dataclass(frozen=True)
class THGSConfig:
    """Time-varying hierarchical gradient sparsification (paper Alg. 1, Eq. 1-2)."""

    s0: float = 0.1            # initial (layer-1) sparsity rate, Eq. 1
    alpha: float = 0.8         # per-layer attenuation factor, Eq. 1
    s_min: float = 0.01        # lower bound of the layer schedule, Eq. 1
    # Eq. 2 time-varying round schedule: R <- (alpha_t + beta - t/T) * R
    time_varying: bool = True
    alpha_t: float = 0.8       # constant attenuation factor of Eq. 2
    r_min: float = 0.001       # lower bound of the round schedule
    # Selector: 'exact' lax.top_k | 'sampled' threshold from a subsample |
    # 'local' per-shard top-k (used on sharded tensors in the launcher).
    selector: str = "exact"
    sample_frac: float = 0.01  # for selector='sampled'
    # k values are quantized to this many geometric levels so the number of
    # distinct jit specializations over a training run is bounded.
    k_levels: int = 16

    def validate(self) -> None:
        if not (0.0 < self.s0 <= 1.0):
            raise ValueError(f"s0 must be in (0,1], got {self.s0}")
        if not (0.0 < self.alpha <= 1.0):
            raise ValueError(f"alpha must be in (0,1], got {self.alpha}")
        if self.s_min <= 0 or self.s_min > self.s0:
            raise ValueError(f"need 0 < s_min <= s0, got {self.s_min} vs {self.s0}")
        if self.selector not in ("exact", "sampled", "local"):
            raise ValueError(f"unknown selector {self.selector!r}")


@dataclasses.dataclass(frozen=True)
class SecureAggConfig:
    """Sparse-mask secure aggregation (paper Alg. 2, Eq. 3-5)."""

    enabled: bool = True
    # Paper Eq. 4: sigma = p + (k/x) q -> per-pair mask support fraction = k/x
    # with x participants and mask ratio k.  k_mask per pair = ceil(size * mask_ratio / x).
    mask_ratio: float = 0.01
    # Uniform mask distribution support [p, p + q) (paper §3.2).
    p: float = -1.0
    q: float = 2.0
    # Mask values are regenerated from counter-based PRNG each round, never stored.
    seed: int = 0x5EC0DE
    # Shamir threshold fraction: a round's dropped masks are recoverable while
    # at least ceil(threshold * cohort) participants survive (Bonawitz t-of-n;
    # repro/secagg/protocol.py). Below it the round aborts (ThresholdError).
    threshold: float = 0.6

    def k_mask_for(self, size: int, n_clients: int) -> int:
        if not self.enabled or n_clients < 2:
            return 0
        return max(1, int(size * self.mask_ratio / n_clients))

    def t_for(self, n_clients: int) -> int:
        """Shamir threshold t for an n-client cohort (>= 2, <= n)."""
        if n_clients < 2:
            return 0
        import math

        # epsilon-nudged ceil: 0.55 * 100 is 55.00000000000001 in binary
        # floating point, and a bare ceil would demand 56 survivors where the
        # configured fraction says 55
        return min(n_clients,
                   max(2, math.ceil(self.threshold * n_clients - 1e-9)))


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Federated optimization settings (paper §5 experimental protocol)."""

    n_clients: int = 100          # total client population
    clients_per_round: int = 10   # C*K in Eq. 7
    local_steps: int = 5          # local iterations per round
    local_batch: int = 50
    local_lr: float = 0.1
    server_lr: float = 1.0
    prox_mu: float = 0.0          # FedProx proximal coefficient (0 => FedAvg)
    rounds: int = 100             # T in Eq. 2
    algorithm: str = "fedavg"     # 'fedavg' | 'fedprox'


@dataclasses.dataclass
class CommRecord:
    """Bit accounting for one aggregation round (Eq. 6-8).

    ``upload_bits``/``download_bits``/``dense_upload_bits`` are totals under
    the ``BitModel`` the round was logged with (``costs.PAPER_BITS`` unless the
    caller chose otherwise); ``upload_bits`` counts gradient streams only —
    the secure-aggregation control traffic is reported separately as
    ``share_upload_bits``/``share_download_bits`` (phase-1 Shamir shares and
    their relay) and ``recovery_upload_bits`` (phase-3 shares unmasking the
    round's dropped clients). The remaining fields are the *slot-level facts*
    of the round — per-leaf top-k counts ``ks``, per-leaf per-pair mask slots
    ``k_masks``, participant/survivor counts, the Shamir ``threshold`` and the
    dense model size — from which ``repro.sim.ledger.CommLedger`` re-derives
    every total under any accounting (64-bit paper elements vs 32-bit TPU wire
    format) without re-running the round. ``ks`` is empty for dense (no-THGS)
    rounds.
    """

    round: int = 0
    upload_bits: int = 0
    download_bits: int = 0
    dense_upload_bits: int = 0   # what FedAvg would have uploaded
    share_upload_bits: int = 0   # phase-1 Shamir shares, client -> server
    share_download_bits: int = 0  # phase-1 share relay, server -> clients
    recovery_upload_bits: int = 0  # phase-3 shares of the dropped clients
    n_clients: int = 0
    n_survivors: int = 0         # participants whose upload arrived
    threshold: int = 0           # Shamir t (0 = no secure aggregation)
    model_size: int = 0          # dense parameter count
    ks: tuple = ()               # per-leaf top-k slots (sparse rounds only)
    k_masks: tuple = ()          # per-leaf per-pair mask-support slots
    codec: str = "f32"           # stream value codec (core/codecs.py)
    leaf_sizes: tuple = ()       # per-leaf dense sizes (codec index widths)
    staleness: tuple = ()        # per-report staleness taus (async rounds
                                 # only — empty on synchronous rounds)
    dp_clip: float = 0.0         # per-client L2 clip S (0 = no DP clipping)
    dp_sigma: float = 0.0        # DP cohort-sum noise multiplier z (0 = none)
    dp_delta: float = 0.0        # accountant target delta (0 = n/a)

    @property
    def compression(self) -> float:
        return self.dense_upload_bits / max(self.upload_bits, 1)


def tree_size(tree: PyTree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree: PyTree, dtype=None) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def quantize_k(k: int, size: int, levels: int) -> int:
    """Snap k to one of `levels` geometric levels of `size` to bound recompiles."""
    if k <= 1:
        return 1
    if k >= size:
        return size
    import math

    # geometric grid between 1 and size
    pos = math.log(k) / math.log(size)  # in (0, 1)
    snapped = round(pos * levels) / levels
    return max(1, min(size, int(round(size ** snapped))))
