"""Communication-cost accounting (paper §5.2, Eq. 6-8).

The paper counts a sparse element as 96 bit (64-bit float value + 32-bit index)
and a dense element as 64 bit. On TPU we transmit float32 values (64 bit/element
sparse, 32 bit dense); both accountings are reported so EXPERIMENTS.md can compare
against the paper's Table 2 like-for-like.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.types import CommRecord


@dataclasses.dataclass(frozen=True)
class BitModel:
    value_bits: int = 64
    index_bits: int = 32

    def sparse_bits(self, k_total: int) -> int:
        return k_total * (self.value_bits + self.index_bits)

    def dense_bits(self, size: int) -> int:
        return size * self.value_bits


PAPER_BITS = BitModel(value_bits=64, index_bits=32)   # Eq. 6: 96 bit / element
TPU_BITS = BitModel(value_bits=32, index_bits=32)     # f32 + int32


def upload_bits_sparse(ks: Sequence[int], k_masks: Sequence[int], n_pairs: int,
                       bits: BitModel = PAPER_BITS) -> int:
    """Per-client upload for one round: top-k slots + per-pair mask slots (Eq. 6)."""
    total_slots = sum(ks) + n_pairs * sum(k_masks)
    return bits.sparse_bits(total_slots)


def upload_bits_dense(model_size: int, bits: BitModel = PAPER_BITS) -> int:
    return bits.dense_bits(model_size)


def round_record(
    round_t: int,
    model_size: int,
    ks: Sequence[int],
    k_masks: Sequence[int],
    n_clients: int,
    bits: BitModel = PAPER_BITS,
) -> CommRecord:
    """Eq. 7-8 for one aggregation round: uploads are sparse, downloads dense."""
    up = n_clients * upload_bits_sparse(ks, k_masks, max(n_clients - 1, 0), bits)
    down = n_clients * upload_bits_dense(model_size, bits)
    dense_up = n_clients * upload_bits_dense(model_size, bits)
    return CommRecord(
        round=round_t,
        upload_bits=up,
        download_bits=down,
        dense_upload_bits=dense_up,
        n_clients=n_clients,
    )


def total_upload_to_convergence(
    n_rounds: int, per_round_bits: int
) -> int:
    """Eq. 7: c = n_rounds * (C*K) * c_up, with per_round_bits already summed
    over the C*K selected clients."""
    return n_rounds * per_round_bits
