"""Communication-cost accounting (paper §5.2, Eq. 6-8).

The paper counts a sparse element as 96 bit (64-bit float value + 32-bit index)
and a dense element as 64 bit. On TPU we transmit float32 values (64 bit/element
sparse, 32 bit dense); both accountings are reported so EXPERIMENTS.md can compare
against the paper's Table 2 like-for-like.

This module is the single source of truth for bits-on-the-wire: the reference
server (core/fedavg.py) logs each round through :func:`round_record` /
:func:`dense_round_record`, and the simulation ledger
(repro/sim/ledger.py) replays the same formulas under both
:data:`PAPER_BITS` and :data:`TPU_BITS`, so ledger totals and per-round
records can never drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.types import CommRecord


@dataclasses.dataclass(frozen=True)
class BitModel:
    """Wire format of one transmitted element.

    Parameters
    ----------
    value_bits : int
        Bits per transmitted value (64 in the paper's double-precision
        accounting, 32 for the float32 TPU wire format).
    index_bits : int
        Bits per sparse index (int32 everywhere).
    """

    value_bits: int = 64
    index_bits: int = 32

    def sparse_bits(self, k_total: int) -> int:
        """Bits for ``k_total`` sparse (index, value) slots — Eq. 6's
        per-element cost times the slot count."""
        return k_total * (self.value_bits + self.index_bits)

    def dense_bits(self, size: int) -> int:
        """Bits for a dense tensor of ``size`` elements (values only)."""
        return size * self.value_bits

    def share_bits(self) -> int:
        """Bits for one Shamir share on the wire: a GF(2^61-1) field element
        (64-bit) plus the holder/owner tag at ``index_bits``. Identical under
        both accountings' value widths — shares are control-plane integers,
        not gradient values."""
        return 64 + self.index_bits


PAPER_BITS = BitModel(value_bits=64, index_bits=32)   # Eq. 6: 96 bit / element
TPU_BITS = BitModel(value_bits=32, index_bits=32)     # f32 + int32


def upload_bits_sparse(ks: Sequence[int], k_masks: Sequence[int], n_pairs: int,
                       bits: BitModel = PAPER_BITS, *, codec: str = "f32",
                       leaf_sizes: Sequence[int] = ()) -> int:
    """Per-client upload bits for one sparse round (Eq. 6).

    With the default ``f32`` codec one client transmits, per leaf, its ``k``
    top-k slots plus ``k_mask`` mask-support slots toward each of its
    ``n_pairs`` active peers (the gated self-pair slot is never on the wire),
    i.e. ``sum(ks) + n_pairs * sum(k_masks)`` unified-stream slots in total at
    ``bits.sparse_bits`` per slot. With a quantized codec (core/codecs.py,
    DESIGN.md §12) the wire is the packed word stream instead — delta-packed
    indices at ``index_width(size)`` bits, value fields at the codec's width,
    plus the per-row scale — an *exact* static function of ``(k, size,
    codec)``, identical under both BitModels (the packed words are the wire;
    there is no wider "paper" element to widen).

    Parameters
    ----------
    ks : sequence of int
        Per-leaf top-k slot counts for this round.
    k_masks : sequence of int
        Per-leaf *per-pair* mask-support slot counts (zeros when secure
        aggregation is off; must be all-zero for quantized codecs).
    n_pairs : int
        Active mask pairs per client — ``n_participants - 1``.
    bits : BitModel
        Wire format; defaults to the paper's 96-bit sparse element.
    codec : str
        Stream value codec; non-f32 switches to packed-word accounting.
    leaf_sizes : sequence of int
        Per-leaf dense sizes, aligned with ``ks`` — required for quantized
        codecs (the delta index width is a function of the leaf size).

    Returns
    -------
    int
        Upload bits for one client.
    """
    if codec != "f32":
        from repro.core import codecs

        codecs.reject_codec_with_masks(codec, any(km > 0 for km in k_masks))
        if len(leaf_sizes) != len(ks):
            raise ValueError(
                "quantized-codec accounting needs leaf_sizes aligned with "
                f"ks, got {len(leaf_sizes)} vs {len(ks)}")
        return sum(codecs.wire_bits(k, s, codec)
                   for k, s in zip(ks, leaf_sizes))
    total_slots = sum(ks) + n_pairs * sum(k_masks)
    return bits.sparse_bits(total_slots)


def upload_bits_dense(model_size: int, bits: BitModel = PAPER_BITS) -> int:
    """Per-client dense (FedAvg baseline) upload bits: ``model_size`` values."""
    return bits.dense_bits(model_size)


def share_upload_bits(n_clients: int, bits: BitModel = PAPER_BITS) -> int:
    """Phase-1 Shamir traffic: every participant uploads one share of its DH
    private key per peer (the self-share stays local) — ``C·(C-1)`` shares.
    The server's relay of the same shares is the matching download."""
    return n_clients * max(n_clients - 1, 0) * bits.share_bits()


def recovery_upload_bits(threshold: int, n_dropped: int,
                         bits: BitModel = PAPER_BITS) -> int:
    """Phase-3 unmasking traffic: the server queries exactly ``threshold``
    survivors for their share of each dropped client's key."""
    return threshold * n_dropped * bits.share_bits()


def round_record(
    round_t: int,
    model_size: int,
    ks: Sequence[int],
    k_masks: Sequence[int],
    n_clients: int,
    bits: BitModel = PAPER_BITS,
    *,
    n_survivors: Optional[int] = None,
    threshold: int = 0,
    codec: str = "f32",
    leaf_sizes: Sequence[int] = (),
    staleness: Sequence[int] = (),
    dp_clip: float = 0.0,
    dp_sigma: float = 0.0,
    dp_delta: float = 0.0,
) -> CommRecord:
    """Eq. 7-8 accounting for one sparse aggregation round.

    Uploads are sparse unified streams from the ``n_survivors`` clients whose
    upload actually arrived (every participant still *transmits toward*
    ``n_clients - 1`` peers — the pair count is agreed before dropout is
    known); downloads are the dense model to every participant. The dense
    baseline column charges every participant a full dense upload. When the
    round ran secure aggregation (any ``k_masks`` > 0), the Bonawitz control
    traffic is charged separately: phase-1 Shamir shares + relay, and — with
    ``threshold`` set and dropouts present — the phase-3 recovery shares.

    Parameters
    ----------
    round_t : int
        Round index (stored in the record).
    model_size : int
        Dense parameter count of the model.
    ks, k_masks : sequence of int
        Per-leaf top-k and per-pair mask slot counts (see
        :func:`upload_bits_sparse`).
    n_clients : int
        Participants in the round (selected cohort, ``C*K`` in Eq. 7).
    bits : BitModel
        Wire format for the logged totals.
    n_survivors : int, optional
        Clients whose upload arrived; defaults to ``n_clients`` (no dropout).
    threshold : int
        The round protocol's Shamir t (repro/secagg); 0 when secure
        aggregation (or its recovery path) is off.
    codec : str
        Stream value codec of the round's wire (core/codecs.py); non-f32
        switches the upload to packed-word accounting.
    leaf_sizes : sequence of int
        Per-leaf dense sizes aligned with ``ks`` — a slot-level fact stored on
        the record so the ledger can re-derive codec wire sizes later.
    staleness : sequence of int
        Per-report staleness taus for async (FedBuff-style) updates; empty on
        synchronous rounds. A stored fact — the bit totals are unaffected
        (each buffered report uploads the same sparse stream).
    dp_clip, dp_sigma, dp_delta : float
        Distributed-DP facts of the round (core/dp.py, DESIGN.md §15): the
        per-client L2 clip S, the cohort-sum noise multiplier z and the
        accountant's target δ. Stored facts only — the noise rides existing
        stream slots, so the bit totals are unaffected. 0.0 (the default)
        means the corresponding mechanism was off.

    Returns
    -------
    CommRecord
        Totals under ``bits`` plus the slot-level facts, so any other
        accounting can be re-derived later (repro/sim/ledger.py).
    """
    if codec != "f32":
        from repro.core import codecs

        codecs.reject_codec_with_masks(codec, any(km > 0 for km in k_masks))
    surv = n_clients if n_survivors is None else n_survivors
    up = surv * upload_bits_sparse(ks, k_masks, max(n_clients - 1, 0), bits,
                                   codec=codec, leaf_sizes=leaf_sizes)
    down = n_clients * upload_bits_dense(model_size, bits)
    dense_up = n_clients * upload_bits_dense(model_size, bits)
    secagg = any(km > 0 for km in k_masks)
    share_up = share_upload_bits(n_clients, bits) if secagg else 0
    recovery_up = (recovery_upload_bits(threshold, n_clients - surv, bits)
                   if secagg else 0)
    return CommRecord(
        round=round_t,
        upload_bits=up,
        download_bits=down,
        dense_upload_bits=dense_up,
        share_upload_bits=share_up,
        share_download_bits=share_up,
        recovery_upload_bits=recovery_up,
        n_clients=n_clients,
        n_survivors=surv,
        threshold=threshold if secagg else 0,
        model_size=model_size,
        ks=tuple(int(k) for k in ks),
        k_masks=tuple(int(k) for k in k_masks),
        codec=codec,
        leaf_sizes=tuple(int(s) for s in leaf_sizes),
        staleness=tuple(int(t) for t in staleness),
        dp_clip=float(dp_clip),
        dp_sigma=float(dp_sigma),
        dp_delta=float(dp_delta),
    )


def dense_round_record(
    round_t: int,
    model_size: int,
    n_clients: int,
    bits: BitModel = PAPER_BITS,
    *,
    n_survivors: Optional[int] = None,
) -> CommRecord:
    """Accounting for one dense (no-THGS) round: FedAvg/FedProx baselines.

    Survivors upload the full dense delta; every participant downloads the
    dense model. ``ks``/``k_masks`` stay empty, which is how downstream
    consumers distinguish dense from sparse rounds.
    """
    surv = n_clients if n_survivors is None else n_survivors
    return CommRecord(
        round=round_t,
        upload_bits=surv * upload_bits_dense(model_size, bits),
        download_bits=n_clients * upload_bits_dense(model_size, bits),
        dense_upload_bits=n_clients * upload_bits_dense(model_size, bits),
        n_clients=n_clients,
        n_survivors=surv,
        model_size=model_size,
    )


def total_upload_to_convergence(
    n_rounds: int, per_round_bits: int
) -> int:
    """Eq. 7: c = n_rounds * (C*K) * c_up, with per_round_bits already summed
    over the C*K selected clients."""
    return n_rounds * per_round_bits
