"""Stream wire-format codecs: quantized values + delta-packed sparse indices.

The StreamCodec stage (DESIGN.md §12) sits between the unified-stream encode
and the all_gather: per block row it quantizes the ``k`` stream values to a
low-bit integer grid (``int8``/``int4`` symmetric scale quantization, ``1bit``
sign with a mean-magnitude scale — Beguier et al., arXiv 2007.14861), absorbs
the quantization error into the THGS error-feedback residuals, and packs both
streams dense: values as two's-complement fields of ``value_bits`` bits,
indices sorted and delta-encoded at ``index_width(m) = ceil(log2(m))`` bits
per field, bit-packed into uint32 words (kernels/pack.py on TPU, the
chunk-identical ref elsewhere). ``f32`` is the passthrough codec — the only
one that composes with sparse-mask secure aggregation, whose pair masks
cancel bit-exactly only on the f32 2^-24 grid (see core/streams.py).

All sizes here are static functions of ``(k, m, codec)``, so the bit
accounting in :mod:`repro.core.costs` stays derived from slot-level facts
(``CommRecord.ks`` + ``leaf_sizes`` + ``codec``), never estimated.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.ref import packed_words

CODECS = ("f32", "int8", "int4", "1bit")
VALUE_BITS = {"int8": 8, "int4": 4, "1bit": 1}
_QMAX = {"int8": 127, "int4": 7}
SCALE_BITS = 32   # one f32 scale per block row rides alongside the words


def value_bits(codec: str) -> int:
    """Bits per packed value field (host int; quantized codecs only)."""
    return VALUE_BITS[codec]


def index_width(m: int) -> int:
    """Bits per delta-encoded index field for block length ``m`` (host int).

    Row indices are sorted so every delta (and the leading absolute index)
    lies in ``[0, m)`` and fits ``ceil(log2(m))`` bits — a static function of
    the block layout, which keeps the accounting fact-derived.
    """
    return max(1, math.ceil(math.log2(max(m, 2))))


def wire_bits(k: int, size: int, codec: str) -> int:
    """Exact packed wire size of one client's stream for one ``nb == 1`` leaf:
    word-padded delta-packed indices + word-padded value fields + the row
    scale (host int; the accounting twin of :func:`pack_stream_rows`)."""
    if codec not in CODECS or codec == "f32":
        raise ValueError(f"wire_bits needs a quantized codec, got {codec!r}")
    return (32 * packed_words(k, index_width(size))
            + 32 * packed_words(k, value_bits(codec))
            + SCALE_BITS)


def reject_codec_with_masks(codec: str, k_mask: int | bool) -> None:
    """THE codec x secure-aggregation guard (repro.lint RPL003 pins it).

    Every public entry point that accepts both a ``codec`` and a
    secure-aggregation parameter (``sa``/``k_mask``/``pair_seeds``/...)
    must route the combination through this one function — scattered
    hand-rolled rejections drift apart. ``k_mask`` is truthy when masks are
    in play (a slot count or an enabled flag); quantized codecs leave the
    f32 2^-24 grid that the pair masks cancel on, so the pair is rejected.
    """
    if codec != "f32" and k_mask:
        raise ValueError(
            f"codec {codec!r} cannot run under sparse-mask secure "
            "aggregation: pair masks cancel bit-exactly only on the f32 "
            "2^-24 grid (DESIGN.md §12); use codec='f32' until integer-grid "
            "masked quantization lands")


# ------------------------------------------------------------- value codecs
def quantize_rows(vals: jax.Array, codec: str):
    """Quantize f32[..., k] row-wise. Returns ``(q int32[..., k] in
    [-qmax, qmax], scales f32[...])`` with ``dequantize_rows(q, scales)`` the
    wire value. int8/int4: symmetric amax/qmax scaling; 1bit: sign carrier
    with the row's mean magnitude as scale (signSGD-style), the quantization
    error is absorbed into error feedback by the caller."""
    if codec == "1bit":
        scales = jnp.mean(jnp.abs(vals), axis=-1)
        q = jnp.where(vals >= 0, 1, -1).astype(jnp.int32)
        return q, scales
    qmax = _QMAX[codec]
    amax = jnp.max(jnp.abs(vals), axis=-1)
    scales = amax / qmax
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(vals / safe[..., None]), -qmax, qmax)
    return q.astype(jnp.int32), scales


def dequantize_rows(q: jax.Array, scales: jax.Array) -> jax.Array:
    """int32[..., k] lattice points x f32[...] row scales -> f32[..., k]."""
    return q.astype(jnp.float32) * scales[..., None]


# ----------------------------------------------------------- wire pack/unpack
def pack_stream_rows(cols: jax.Array, q: jax.Array, *, m: int, codec: str):
    """Pack sorted per-row stream slots onto the wire.

    ``cols`` int32[..., k] block-local indices, sorted ascending per row;
    ``q`` int32[..., k] quantized values. Returns ``(iwords uint32[..., Wi],
    vwords uint32[..., Wv])`` — indices delta-encoded then packed at
    ``index_width(m)`` bits, values packed two's-complement at
    ``value_bits(codec)`` bits (1bit: the field is ``q > 0``).
    """
    from repro.kernels import ops

    lead, k = cols.shape[:-1], cols.shape[-1]
    c2 = cols.reshape(-1, k)
    q2 = q.reshape(-1, k)
    deltas = jnp.concatenate([c2[:, :1], c2[:, 1:] - c2[:, :-1]],
                             axis=1).astype(jnp.uint32)
    iwords = ops.bitpack_rows(deltas, width=index_width(m))
    vb = value_bits(codec)
    if codec == "1bit":
        u = (q2 > 0).astype(jnp.uint32)
    else:
        u = (q2 & ((1 << vb) - 1)).astype(jnp.uint32)  # two's complement field
    vwords = ops.bitpack_rows(u, width=vb)
    return (iwords.reshape(*lead, iwords.shape[-1]),
            vwords.reshape(*lead, vwords.shape[-1]))


def unpack_stream_rows(iwords: jax.Array, vwords: jax.Array, *, k: int,
                       m: int, codec: str):
    """Inverse of :func:`pack_stream_rows`: words -> ``(cols int32[..., k]
    sorted, q int32[..., k])`` — bit-exact round trip for any duplicate-free
    monotone index row and any lattice value in codec range."""
    from repro.kernels import ops

    lead = iwords.shape[:-1]
    d = ops.bitunpack_rows(iwords.reshape(-1, iwords.shape[-1]), k=k,
                           width=index_width(m))
    cols = jnp.cumsum(d.astype(jnp.int32), axis=-1)
    vb = value_bits(codec)
    u = ops.bitunpack_rows(vwords.reshape(-1, vwords.shape[-1]), k=k,
                           width=vb)
    if codec == "1bit":
        q = 2 * u.astype(jnp.int32) - 1
    else:
        ui = u.astype(jnp.int32)
        q = jnp.where(ui >= (1 << (vb - 1)), ui - (1 << vb), ui)
    return cols.reshape(*lead, k), q.reshape(*lead, k)
