"""Sparsity-rate schedules: paper Eq. 1 (hierarchical) and Eq. 2 (time-varying).

All schedule math runs host-side; the resulting per-leaf ``k`` values are Python
ints baked into the traced step function (quantized to bound recompilation).
"""
from __future__ import annotations

import math
from typing import Sequence

from repro.core.types import THGSConfig, quantize_k


def layer_rates(cfg: THGSConfig, n_layers: int) -> list[float]:
    """Eq. 1: s_1 = s0; s_i = max(s_{i-1} * alpha, s_min).

    Layer order follows the model's parameter-tree order (input->output), matching
    the paper's observation that deeper layers tolerate stronger sparsification.
    """
    rates: list[float] = []
    for i in range(n_layers):
        if i == 0:
            rates.append(cfg.s0)
            continue
        s_next = rates[-1] * cfg.alpha
        rates.append(s_next if s_next > cfg.s_min else cfg.s_min)
    return rates


def round_rate(
    cfg: THGSConfig,
    base_rate: float,
    t: int,
    total_rounds: int,
    loss_prev: float | None,
    loss_curr: float | None,
) -> float:
    """Eq. 2: R <- (alpha + beta - t/T) * R, clamped to [r_min, 1].

    beta is the client's loss change rate (paper Alg. 2 line 8:
    beta = (loss_0 - loss_k) / loss_k); when no loss history exists yet we take
    beta = 0 (no amplification).
    """
    if not cfg.time_varying:
        return base_rate
    if loss_prev is None or loss_curr is None or abs(loss_curr) < 1e-12:
        beta = 0.0
    else:
        beta = (loss_prev - loss_curr) / abs(loss_curr)
        beta = max(-1.0, min(1.0, beta))  # clip pathological spikes
    factor = cfg.alpha_t + beta - (t / max(total_rounds, 1))
    r = base_rate * factor
    return max(cfg.r_min, min(1.0, r))


def leaf_ks(
    cfg: THGSConfig,
    leaf_sizes: Sequence[int],
    t: int = 0,
    total_rounds: int = 1,
    loss_prev: float | None = None,
    loss_curr: float | None = None,
) -> list[int]:
    """Static per-leaf top-k counts for round ``t`` (hierarchical x time-varying)."""
    per_layer = layer_rates(cfg, len(leaf_sizes))
    ks = []
    for size, s in zip(leaf_sizes, per_layer):
        r = round_rate(cfg, s, t, total_rounds, loss_prev, loss_curr)
        k = max(1, int(math.ceil(size * r)))
        ks.append(quantize_k(k, size, cfg.k_levels))
    return ks
