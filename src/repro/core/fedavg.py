"""Federated optimization loop: FedAvg / FedProx clients + THGS/secure-agg server.

The transmitted "gradient update" of the paper is the local model delta after
``local_steps`` of SGD (McMahan et al. 2017); THGS + secure aggregation compress
that delta. This module is the single-host reference implementation used by the
paper-scale benchmarks and tests; the datacenter-mesh variant lives in
repro/launch/train.py and shares the encode/aggregate engine (core/streams.py).

Since the stream-engine refactor (DESIGN.md §3) a round is three batched,
jitted programs instead of a per-client Python loop:

  1. ``batched_client_update`` — local SGD for every participant, vmapped over
     the stacked client batches (one XLA dispatch per round);
  2. ``streams.encode_leaf_batch`` per leaf — the unified top-k ∪ mask-support
     encode for all clients at once (counter-based pair seeds from the
     repro/secagg round protocol: DH-agreed pair secrets, Shamir-shared for
     dropout recovery);
  3. ``streams.decode_leaf_batch`` per leaf — one fused scatter-add over every
     client's stream, with per-client weights, survivor gating and Bonawitz
     reconstruction of dropped clients' unpaired masks from their
     Shamir-recombined keys (protocol phase 3).

Weighted aggregation is client-side (weights scale the gradient values before
masking, so non-uniform weights keep mask cancellation exact); the server
normalizes by the survivors' total weight after the masks have cancelled.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from repro.core import costs, schedules
from repro.core import streams as se
from repro.core.types import (
    CommRecord,
    FedConfig,
    PyTree,
    SecureAggConfig,
    THGSConfig,
    tree_zeros_like,
)

LossFn = Callable[[PyTree, Any], jax.Array]


def _client_update(
    params: PyTree,
    batches: Any,  # stacked leading axis = local_steps
    loss_fn: LossFn,
    local_steps: int,
    lr: float,
    prox_mu: float = 0.0,
) -> tuple[PyTree, jax.Array]:
    """Local SGD (optionally FedProx-proximal); returns (delta, mean loss)."""
    grad_fn = jax.value_and_grad(loss_fn)

    def prox_term(p):
        if prox_mu == 0.0:
            return 0.0
        sq = sum(
            jnp.sum((a - b) ** 2)
            for a, b in zip(jax.tree_util.tree_leaves(p),
                            jax.tree_util.tree_leaves(params))
        )
        return 0.5 * prox_mu * sq

    def step(p, batch):
        loss, g = grad_fn(p, batch)
        if prox_mu != 0.0:
            gp = jax.grad(lambda q: prox_term(q))(p)
            g = jax.tree_util.tree_map(jnp.add, g, gp)
        p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
        return p, loss

    new_params, losses = jax.lax.scan(
        step, params, batches, length=local_steps
    )
    delta = jax.tree_util.tree_map(lambda a, b: a - b, new_params, params)
    return delta, jnp.mean(losses)


@partial(jax.jit, static_argnames=("loss_fn", "local_steps", "prox_mu"))
def client_update(
    params: PyTree,
    batches: Any,
    loss_fn: LossFn,
    local_steps: int,
    lr: float,
    prox_mu: float = 0.0,
) -> tuple[PyTree, jax.Array]:
    """Single-client entry (kept for callers that step one client at a time)."""
    return _client_update(params, batches, loss_fn, local_steps, lr, prox_mu)


@partial(jax.jit, static_argnames=("loss_fn", "local_steps", "prox_mu"))
def batched_client_update(
    params: PyTree,
    batches_stacked: Any,   # leading axis = clients, then local_steps
    loss_fn: LossFn,
    local_steps: int,
    lr: float,
    prox_mu: float = 0.0,
) -> tuple[PyTree, jax.Array]:
    """All participants' local SGD in one vmapped program.

    Returns (deltas stacked [C, ...], losses [C])."""
    return jax.vmap(
        lambda b: _client_update(params, b, loss_fn, local_steps, lr, prox_mu)
    )(batches_stacked)


@functools.lru_cache(maxsize=None)
def _sharded_update_program(mesh, loss_fn: LossFn, local_steps: int,
                            prox_mu: float):
    """Cached shard_map twin of ``batched_client_update`` for a clients mesh."""
    P = jax.sharding.PartitionSpec

    def body(params, batches_l, lr):
        return jax.vmap(
            lambda b: _client_update(params, b, loss_fn, local_steps, lr,
                                     prox_mu)
        )(batches_l)

    fn = se.shard_map_clients(
        body, mesh,
        in_specs=(P(), P(se.CLIENT_AXIS), P()),
        out_specs=(P(se.CLIENT_AXIS), P(se.CLIENT_AXIS)))
    return jax.jit(fn)


def batched_client_update_sharded(
    mesh,
    params: PyTree,
    batches_stacked: Any,   # leading axis = clients, then local_steps
    loss_fn: LossFn,
    local_steps: int,
    lr: float,
    prox_mu: float = 0.0,
) -> tuple[PyTree, jax.Array]:
    """Device-sharded local SGD: clients partitioned over the ``clients``
    mesh axis, each device vmapping its shard through the same
    ``_client_update`` program. Per-client math is independent, so deltas are
    bit-exact with ``batched_client_update`` (losses may differ in the last
    ulp from reduction layout; the parity tests pin the deltas and the
    decoded server update)."""
    fn = _sharded_update_program(mesh, loss_fn, local_steps, float(prox_mu))
    return fn(params, batches_stacked, lr)


@dataclasses.dataclass
class FederatedState:
    params: PyTree
    residuals: dict[int, PyTree]        # per-client error feedback
    losses: dict[int, float]            # last local loss per client (for Eq. 2 beta)
    round: int = 0
    comm_log: list[CommRecord] = dataclasses.field(default_factory=list)


def init_state(params: PyTree, fed: FedConfig) -> FederatedState:
    return FederatedState(
        params=params,
        residuals={c: tree_zeros_like(params) for c in range(fed.n_clients)},
        losses={},
    )


def _mean_or_none(vals):
    vals = [v for v in vals if v is not None]
    return float(sum(vals) / len(vals)) if vals else None


def run_round(
    state: FederatedState,
    client_batches: dict[int, Any],
    loss_fn: LossFn,
    fed: FedConfig,
    thgs: THGSConfig | None,
    sa: SecureAggConfig,
    bits: costs.BitModel = costs.PAPER_BITS,
    client_weights: Mapping[int, float] | None = None,
    dropped: Sequence[int] = (),
    protocol=None,
    mesh=None,
    codec: str = "f32",
    topology: str = "flat",
    tree_groups: int = 0,
    dp=None,
) -> FederatedState:
    """One aggregation round over the provided participating clients.

    thgs=None -> dense FedAvg/FedProx baseline (optionally dense-masked SA).
    ``client_weights`` gives per-client aggregation weights (e.g. local data
    counts); unweighted clients default to 1. ``dropped`` lists participants
    that completed the mask agreement but whose upload never arrived — their
    streams are excluded and the survivors' unpaired masks toward them are
    regenerated from Shamir-reconstructed pair seeds and cancelled server-side
    (Bonawitz dropout recovery, repro/secagg/protocol.py; raises
    ``secagg.ThresholdError`` when fewer than the Shamir threshold survive).
    ``protocol`` injects a pre-built ``RoundProtocol`` (tests); by default the
    round runs its own setup over the participants.

    ``mesh`` opts into the device-sharded client-parallel round (DESIGN.md
    §11): a 1-D ``clients`` mesh (launch/mesh.clients_mesh_for) partitions the
    cohort over devices — local SGD, THGS encode and pair-mask PRNG run
    per-shard under shard_map, and the server update is one sparse-stream
    all_gather + the identical fused scatter-add, bit-exact with the vmap
    path. When the mesh cannot host the cohort (None, 1 device, or cohort not
    divisible) the single-device vmap path runs, unchanged.

    ``codec`` selects the stream wire format (core/codecs.py, DESIGN.md §12):
    ``'f32'`` is the passthrough; ``'int8'``/``'int4'``/``'1bit'`` quantize
    the stream values (quantization error absorbed into the THGS error
    feedback) and delta-pack the indices, and the round is accounted at the
    exact packed wire size. Quantized codecs require THGS and are rejected
    under secure aggregation — pair masks cancel bit-exactly only on the f32
    grid.

    ``topology`` selects the aggregation tree (DESIGN.md §13): ``'flat'`` is
    the single fused scatter-add; ``'tree'`` splits the decode across
    ``tree_groups`` sub-aggregators (0 = auto, ~sqrt(cohort)), each owning a
    contiguous index range of the dense buffer, combined by concatenation —
    bit-exact with flat (params, residuals, CommLedger), including secagg
    dropout recovery, for any group count. Requires THGS.

    ``dp`` takes a ``core.dp.DPConfig`` (DESIGN.md §15): per-client global-L2
    clipping of the error-feedback accumulator ``residual + delta`` (the
    encoder's actual input, so the bound covers the full emitted stream),
    and with ``sigma > 0`` the round releases gradient values on a PUBLIC
    common support (no data-dependent index leakage) with grid-exact
    Gaussian noise on every released slot, injected under the pair masks and
    seeded per (round, client) so resume replays it. Requires THGS, the f32
    codec, and uniform client weights — non-uniform ``client_weights`` are
    rejected here (a weighted stream would scale a contribution past the
    clip bound S), mirroring the sim config's ``weight_by_data_count``
    rejection. ``None`` or an inactive config (``clip=inf, sigma=0``) leaves
    the round bit-identical to the pre-DP path.

    All participants' batch pytrees must share one structure and one set of
    array shapes (they are stacked on a leading client axis for the batched
    local-SGD program); pad ragged local data to fixed [steps, batch] first,
    as data/federated.py::client_batches does.
    """
    if topology not in ("flat", "tree"):
        raise ValueError(f"unknown topology {topology!r}")
    if topology == "tree" and thgs is None:
        raise ValueError("topology='tree' requires THGS sparse streams; "
                         "dense rounds have no stream decode to shard")
    dp_active = dp is not None and dp.active
    if dp_active:
        dp.validate()
        if thgs is None:
            raise ValueError(
                "dp requires THGS sparse streams; the DP noise rides the "
                "unified stream's transmitted slots (thgs is None)")
        from repro.core.dp import reject_codec_with_noise

        reject_codec_with_noise(codec, dp.sigma)
        if client_weights and any(
                float(w) != 1.0 for w in client_weights.values()):
            raise ValueError(
                "dp requires uniform client weights: weights scale the "
                "stream values before masking, so a weight != 1.0 would "
                "scale that client's contribution past the clip bound S "
                "the accountant calibrates noise against")
    participants = sorted(client_batches.keys())
    C = len(participants)
    sharded = se.can_shard_clients(mesh, C)
    dropped = set(dropped)
    assert dropped <= set(participants), "dropped must be participants"
    survivors = [c for c in participants if c not in dropped]
    assert survivors, "a round needs at least one surviving client"
    alive = jnp.asarray([c not in dropped for c in participants], bool)
    w_list = [float(client_weights.get(c, 1.0)) if client_weights else 1.0
              for c in participants]
    w_vec = jnp.asarray(w_list, jnp.float32)
    w_surv_total = sum(w for w, c in zip(w_list, participants)
                       if c not in dropped)

    leaves, treedef = jax.tree_util.tree_flatten(state.params)
    leaf_shapes = [x.shape for x in leaves]
    leaf_dtypes = [x.dtype for x in leaves]
    model_size = sum(x.size for x in leaves)

    # ---- 1. all clients' local SGD, one vmapped dispatch ----
    batches_stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[client_batches[c] for c in participants])
    if sharded:
        batches_stacked = se.shard_client_tree(batches_stacked, mesh)
        deltas_stacked, losses = batched_client_update_sharded(
            mesh,
            state.params,
            batches_stacked,
            loss_fn,
            fed.local_steps,
            fed.local_lr,
            fed.prox_mu if fed.algorithm == "fedprox" else 0.0,
        )
    else:
        deltas_stacked, losses = batched_client_update(
            state.params,
            batches_stacked,
            loss_fn,
            fed.local_steps,
            fed.local_lr,
            fed.prox_mu if fed.algorithm == "fedprox" else 0.0,
        )
    losses_list = [float(x) for x in losses]

    if thgs is not None:
        # per-(round, client) noise seeds and the round's public common-
        # support seed, derived host-side so the stream is replayable from
        # config + round alone (resume, sharded parity)
        dp_sigma_c = dp.sigma_client(C) if dp_active else 0.0
        dp_noised = dp_active and dp.noised
        dp_seeds = (jnp.asarray(dp.client_seeds(state.round, participants))
                    if dp_noised else None)
        dp_sup_seed = dp.support_seed(state.round) if dp_noised else 0
        # Eq. 2's beta from the federation-mean loss trajectory: one static
        # per-leaf k for the whole batched round (per-client k would make the
        # stacked stream shapes ragged — see DESIGN.md §3).
        loss_prev = _mean_or_none([state.losses.get(c) for c in participants])
        loss_curr = _mean_or_none(losses_list)
        ks = schedules.leaf_ks(
            thgs,
            [x.size for x in leaves],
            t=state.round,
            total_rounds=fed.rounds,
            loss_prev=loss_prev,
            loss_curr=loss_curr,
        )
        use_masks = sa.enabled and C >= 2
        se.reject_codec_with_masks(codec, use_masks)
        if use_masks:
            # the round protocol: DH pair secrets + Shamir shares (phases
            # 0-1); layering note — secagg sits beside core, this local
            # import is the one sanctioned upward edge (DESIGN.md §10)
            from repro.secagg.protocol import RoundProtocol

            proto = (protocol if protocol is not None
                     else RoundProtocol.setup(sa, participants, state.round))
            pair_seeds, pair_signs = proto.pair_seed_matrix()
            recovery_seeds = (proto.recover_seeds(survivors, sorted(dropped))
                              if dropped else None)
        else:
            proto = None
            pair_seeds = pair_signs = recovery_seeds = None

        delta_leaves = jax.tree_util.tree_leaves(deltas_stacked)
        res_per_client = [jax.tree_util.tree_leaves(state.residuals[c])
                          for c in participants]
        res_stacked = [jnp.stack([rl[i] for rl in res_per_client])
                       for i in range(len(leaves))]
        if dp_active and dp.clips:
            # per-client global-L2 clip of the ENCODER INPUT — the error-
            # feedback accumulator residual + delta — so the sensitivity
            # bound S holds for the full stream the client emits (the
            # residual carries untransmitted mass across rounds; clipping
            # the fresh delta alone would not bound it). The clipped
            # accumulator becomes the encode's update with a zeroed residual
            # source; compliant clients scale by exactly 1.0 (core/dp.py).
            from repro.core.dp import clip_client_updates

            acc_tree = jax.tree_util.tree_unflatten(
                treedef,
                [d.astype(jnp.float32) + r.astype(jnp.float32)
                 for d, r in zip(delta_leaves, res_stacked)])
            delta_leaves = jax.tree_util.tree_leaves(
                clip_client_updates(acc_tree, clip=float(dp.clip)))
            res_stacked = [jnp.zeros_like(r) for r in res_stacked]
        if sharded:
            res_stacked = [se.shard_client_tree(r, mesh) for r in res_stacked]

        groups = tree_groups if tree_groups > 0 else max(
            2, int(round(C ** 0.5)))

        agg_leaves, new_res_leaves = [], []
        ks_acct, k_masks_acct, leaf_sizes_acct = [], [], []
        for leaf_id, (d_st, r_st, k, shape) in enumerate(
                zip(delta_leaves, res_stacked, ks, leaf_shapes)):
            size = leaves[leaf_id].size
            k_mask = sa.k_mask_for(size, C) if use_masks else 0
            if sharded:
                # ---- 2+3. client-parallel encode + fused decode: one
                # shard_map program per leaf (DESIGN.md §11) ----
                dense, new_res = se.encode_decode_leaf_sharded(
                    mesh, d_st, r_st, k=k, nb=1, m=size, size=size,
                    selector=thgs.selector, sample_frac=thgs.sample_frac,
                    pair_seeds=pair_seeds, pair_signs=pair_signs,
                    recovery_seeds=recovery_seeds if dropped else None,
                    alive=alive if dropped else None,
                    k_mask=k_mask, mask_p=sa.p, mask_q=sa.q,
                    leaf_id=leaf_id, weights=w_vec, codec=codec,
                    topology=topology, tree_groups=groups,
                    dp_sigma=dp_sigma_c, dp_seeds=dp_seeds,
                    dp_support_seed=dp_sup_seed)
            else:
                # ---- 2. batched unified-stream encode (all clients, one
                # jit) ----
                streams_b, new_res = se.encode_leaf_batch(
                    d_st, r_st, k=k, nb=1, m=size, size=size,
                    selector=thgs.selector, sample_frac=thgs.sample_frac,
                    pair_seeds=pair_seeds, pair_signs=pair_signs,
                    k_mask=k_mask, mask_p=sa.p, mask_q=sa.q,
                    leaf_id=leaf_id, weights=w_vec, codec=codec,
                    dp_sigma=dp_sigma_c, dp_seeds=dp_seeds,
                    dp_support_seed=dp_sup_seed)
                # ---- 3. fused scatter-add decode + dropout recovery ----
                if topology == "tree":
                    dense = se.decode_leaf_tree(
                        streams_b, nb=1, m=size, size=size,
                        splits=se.tree_splits(size, groups),
                        alive=alive if dropped else None,
                        pair_seeds=recovery_seeds if dropped else None,
                        pair_signs=pair_signs if dropped else None,
                        k_mask=k_mask, mask_p=sa.p, mask_q=sa.q,
                        leaf_id=leaf_id)
                else:
                    dense = se.decode_leaf_batch(
                        streams_b, nb=1, m=size, size=size,
                        alive=alive if dropped else None,
                        pair_seeds=recovery_seeds if dropped else None,
                        pair_signs=pair_signs if dropped else None,
                        k_mask=k_mask, mask_p=sa.p, mask_q=sa.q,
                        leaf_id=leaf_id)
            agg_leaves.append(
                (dense / w_surv_total).reshape(shape)
                .astype(leaf_dtypes[leaf_id]))
            # dropped clients transmitted nothing: their full accumulator
            # carries over as error feedback (nothing is lost, only delayed)
            if dropped:
                keep = alive.reshape((C,) + (1,) * len(shape))
                new_res = jnp.where(
                    keep, new_res,
                    (r_st + d_st).astype(new_res.dtype))
            new_res_leaves.append(new_res)
            # wire accounting: the gated self-pair slot (zero value at a
            # duplicated index) is not transmitted — k + (C-1)*k_mask slots
            # per leaf, matching the paper's Eq. 6 payload; leaf_sizes feed
            # the quantized codecs' exact packed-word sizes (core/codecs.py)
            ks_acct.append(min(int(k), size))
            k_masks_acct.append(k_mask)
            leaf_sizes_acct.append(size)

        agg = jax.tree_util.tree_unflatten(treedef, agg_leaves)
        for ci, c in enumerate(participants):
            state.residuals[c] = jax.tree_util.tree_unflatten(
                treedef, [nr[ci] for nr in new_res_leaves])
        rec = costs.round_record(
            state.round, model_size, ks_acct, k_masks_acct,
            n_clients=len(participants), bits=bits,
            n_survivors=len(survivors),
            threshold=proto.t if use_masks else 0,
            codec=codec, leaf_sizes=leaf_sizes_acct,
            # facts-only DP fields: inactive parts stay at the 0.0 defaults
            # so sigma=0/clip=inf records equal pre-DP records bit for bit
            dp_clip=float(dp.clip) if dp_active and dp.clips else 0.0,
            dp_sigma=float(dp.sigma) if dp_active else 0.0,
            dp_delta=float(dp.delta) if dp_active and dp.noised else 0.0)
    else:
        if codec != "f32":
            raise ValueError(
                f"codec {codec!r} requires THGS sparse streams; dense rounds "
                "have no stream wire to quantize (thgs is None)")
        deltas = {c: jax.tree_util.tree_map(lambda x: x[ci], deltas_stacked)
                  for ci, c in enumerate(participants)}
        if sa.enabled:
            from repro.core.secure_agg import dense_masked_update

            # dense Bonawitz has no sparse-support reconstruction: masks are
            # agreed among the survivors (the baseline's re-run assumption)
            masked = []
            for c in survivors:
                leaves_c = jax.tree_util.tree_leaves(deltas[c])
                masked.append([
                    dense_masked_update(x, sa, c, survivors, state.round, i)
                    for i, x in enumerate(leaves_c)
                ])
            summed = [
                sum(m[i] for m in masked) / len(survivors)
                for i in range(len(leaves))
            ]
            agg = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(state.params),
                [s.astype(d) for s, d in zip(summed, leaf_dtypes)],
            )
        else:
            agg = jax.tree_util.tree_map(
                lambda *xs: sum(xs) / len(xs), *[deltas[c] for c in survivors]
            )
        rec = costs.dense_round_record(
            state.round, model_size, n_clients=len(participants), bits=bits,
            n_survivors=len(survivors))

    for ci, c in enumerate(participants):
        state.losses[c] = losses_list[ci]
    state.params = jax.tree_util.tree_map(
        lambda p, d: p + fed.server_lr * d, state.params, agg
    )
    state.comm_log.append(rec)
    state.round += 1
    return state


# -------------------------------------------- async (FedBuff-style) updates
def staleness_weight(tau: int) -> float:
    """FedBuff's polynomial staleness discount ``(1 + tau)^(-1/2)``
    (Nguyen et al. 2022): a report trained on params ``tau`` server updates
    old contributes with this weight. ``tau == 0`` gives weight 1, so an
    all-fresh buffer reproduces the synchronous round exactly."""
    return (1.0 + float(tau)) ** -0.5


@partial(jax.jit, static_argnames=("loss_fn", "local_steps", "prox_mu"))
def batched_client_update_multi(
    params_stacked: PyTree,  # leading axis = reports (per-report stale params)
    batches_stacked: Any,    # leading axis = reports, then local_steps
    loss_fn: LossFn,
    local_steps: int,
    lr: float,
    prox_mu: float = 0.0,
) -> tuple[PyTree, jax.Array]:
    """Async twin of ``batched_client_update``: every report trains from its
    OWN (stale) parameter version, so params are vmapped alongside the
    batches instead of broadcast. Returns (deltas stacked [B, ...],
    losses [B])."""
    return jax.vmap(
        lambda p, b: _client_update(p, b, loss_fn, local_steps, lr, prox_mu)
    )(params_stacked, batches_stacked)


def run_async_update(
    state: FederatedState,
    client_batches: dict[int, Any],
    client_params: Mapping[int, PyTree],
    loss_fn: LossFn,
    fed: FedConfig,
    thgs: THGSConfig,
    bits: costs.BitModel = costs.PAPER_BITS,
    staleness: Mapping[int, int] | None = None,
    client_weights: Mapping[int, float] | None = None,
    codec: str = "f32",
    topology: str = "flat",
    tree_groups: int = 0,
) -> FederatedState:
    """One FedBuff-style buffered server update (DESIGN.md §13).

    The buffer holds one report per client in ``client_batches``: client
    ``c`` ran local SGD from the stale parameter version ``client_params[c]``
    (``staleness[c]`` server updates old) and its THGS-sparsified delta joins
    the aggregate with weight ``staleness_weight(tau) * client_weights[c]``.
    The server applies the weight-normalized aggregate exactly like a
    synchronous round — with ``staleness`` all zero this IS ``run_round``
    bit-exactly (tested in tests/test_async_sim.py).

    Secure aggregation is not supported in async mode: pair masks are agreed
    round-synchronously among a known cohort, which a streaming buffer breaks
    (SimConfig.validate rejects the combination). THGS is required — the
    async path exists to exercise the sparse-stream data plane. Clients in
    one buffer must be distinct: error-feedback residual write-back is
    per-client, and a duplicate's first report would be silently clobbered.
    """
    if thgs is None:
        raise ValueError("run_async_update requires THGS sparse streams")
    if topology not in ("flat", "tree"):
        raise ValueError(f"unknown topology {topology!r}")
    participants = sorted(client_batches.keys())
    B = len(participants)
    assert len(set(participants)) == B, "buffer clients must be distinct"
    staleness = staleness or {}
    taus = [int(staleness.get(c, 0)) for c in participants]
    w_list = [staleness_weight(t) *
              (float(client_weights.get(c, 1.0)) if client_weights else 1.0)
              for c, t in zip(participants, taus)]
    w_vec = jnp.asarray(w_list, jnp.float32)
    w_total = float(sum(w_list))

    leaves, treedef = jax.tree_util.tree_flatten(state.params)
    leaf_shapes = [x.shape for x in leaves]
    leaf_dtypes = [x.dtype for x in leaves]
    model_size = sum(x.size for x in leaves)

    # ---- 1. every report's local SGD from its own stale params ----
    batches_stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[client_batches[c] for c in participants])
    params_stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[client_params[c] for c in participants])
    deltas_stacked, losses = batched_client_update_multi(
        params_stacked, batches_stacked, loss_fn, fed.local_steps,
        fed.local_lr, fed.prox_mu if fed.algorithm == "fedprox" else 0.0)
    losses_list = [float(x) for x in losses]

    loss_prev = _mean_or_none([state.losses.get(c) for c in participants])
    loss_curr = _mean_or_none(losses_list)
    ks = schedules.leaf_ks(
        thgs, [x.size for x in leaves], t=state.round,
        total_rounds=fed.rounds, loss_prev=loss_prev, loss_curr=loss_curr)
    groups = tree_groups if tree_groups > 0 else max(2, int(round(B ** 0.5)))

    delta_leaves = jax.tree_util.tree_leaves(deltas_stacked)
    res_per_client = [jax.tree_util.tree_leaves(state.residuals[c])
                      for c in participants]
    res_stacked = [jnp.stack([rl[i] for rl in res_per_client])
                   for i in range(len(leaves))]

    agg_leaves, new_res_leaves = [], []
    ks_acct, leaf_sizes_acct = [], []
    for leaf_id, (d_st, r_st, k, shape) in enumerate(
            zip(delta_leaves, res_stacked, ks, leaf_shapes)):
        size = leaves[leaf_id].size
        # ---- 2. batched unified-stream encode, staleness-weighted ----
        streams_b, new_res = se.encode_leaf_batch(
            d_st, r_st, k=k, nb=1, m=size, size=size,
            selector=thgs.selector, sample_frac=thgs.sample_frac,
            leaf_id=leaf_id, weights=w_vec, codec=codec)
        # ---- 3. fused decode (flat or hierarchical) ----
        if topology == "tree":
            dense = se.decode_leaf_tree(
                streams_b, nb=1, m=size, size=size,
                splits=se.tree_splits(size, groups))
        else:
            dense = se.decode_leaf_batch(streams_b, nb=1, m=size, size=size)
        agg_leaves.append(
            (dense / w_total).reshape(shape).astype(leaf_dtypes[leaf_id]))
        new_res_leaves.append(new_res)
        ks_acct.append(min(int(k), size))
        leaf_sizes_acct.append(size)

    agg = jax.tree_util.tree_unflatten(treedef, agg_leaves)
    for ci, c in enumerate(participants):
        state.residuals[c] = jax.tree_util.tree_unflatten(
            treedef, [nr[ci] for nr in new_res_leaves])
        state.losses[c] = losses_list[ci]
    rec = costs.round_record(
        state.round, model_size, ks_acct, [0] * len(ks_acct),
        n_clients=B, bits=bits, n_survivors=B, threshold=0,
        codec=codec, leaf_sizes=leaf_sizes_acct, staleness=tuple(taus))
    state.params = jax.tree_util.tree_map(
        lambda p, d: p + fed.server_lr * d, state.params, agg)
    state.comm_log.append(rec)
    state.round += 1
    return state
