"""Federated optimization loop: FedAvg / FedProx clients + THGS/secure-agg server.

The transmitted "gradient update" of the paper is the local model delta after
``local_steps`` of SGD (McMahan et al. 2017); THGS + secure aggregation compress
that delta. This module is the single-host reference implementation used by the
paper-scale benchmarks and tests; the datacenter-mesh variant lives in
repro/launch/train.py and shares the encode/aggregate primitives.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import costs, schedules
from repro.core.secure_agg import aggregate_streams, encode_update
from repro.core.types import (
    CommRecord,
    FedConfig,
    PyTree,
    SecureAggConfig,
    THGSConfig,
    tree_zeros_like,
)

LossFn = Callable[[PyTree, Any], jax.Array]


@partial(jax.jit, static_argnames=("loss_fn", "local_steps", "prox_mu"))
def client_update(
    params: PyTree,
    batches: Any,  # stacked leading axis = local_steps
    loss_fn: LossFn,
    local_steps: int,
    lr: float,
    prox_mu: float = 0.0,
) -> tuple[PyTree, jax.Array]:
    """Local SGD (optionally FedProx-proximal); returns (delta, mean loss)."""
    grad_fn = jax.value_and_grad(loss_fn)

    def prox_term(p):
        if prox_mu == 0.0:
            return 0.0
        sq = sum(
            jnp.sum((a - b) ** 2)
            for a, b in zip(jax.tree_util.tree_leaves(p),
                            jax.tree_util.tree_leaves(params))
        )
        return 0.5 * prox_mu * sq

    def step(p, batch):
        loss, g = grad_fn(p, batch)
        if prox_mu != 0.0:
            gp = jax.grad(lambda q: prox_term(q))(p)
            g = jax.tree_util.tree_map(jnp.add, g, gp)
        p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
        return p, loss

    new_params, losses = jax.lax.scan(
        step, params, batches, length=local_steps
    )
    delta = jax.tree_util.tree_map(lambda a, b: a - b, new_params, params)
    return delta, jnp.mean(losses)


@dataclasses.dataclass
class FederatedState:
    params: PyTree
    residuals: dict[int, PyTree]        # per-client error feedback
    losses: dict[int, float]            # last local loss per client (for Eq. 2 beta)
    round: int = 0
    comm_log: list[CommRecord] = dataclasses.field(default_factory=list)


def init_state(params: PyTree, fed: FedConfig) -> FederatedState:
    return FederatedState(
        params=params,
        residuals={c: tree_zeros_like(params) for c in range(fed.n_clients)},
        losses={},
    )


def run_round(
    state: FederatedState,
    client_batches: dict[int, Any],
    loss_fn: LossFn,
    fed: FedConfig,
    thgs: THGSConfig | None,
    sa: SecureAggConfig,
    bits: costs.BitModel = costs.PAPER_BITS,
) -> FederatedState:
    """One aggregation round over the provided participating clients.

    thgs=None -> dense FedAvg/FedProx baseline (optionally dense-masked SA).
    """
    participants = sorted(client_batches.keys())
    leaves = jax.tree_util.tree_leaves(state.params)
    leaf_shapes = [x.shape for x in leaves]
    leaf_dtypes = [x.dtype for x in leaves]
    model_size = sum(x.size for x in leaves)

    deltas, streams_all = {}, {}
    for c in participants:
        delta, loss = client_update(
            state.params,
            client_batches[c],
            loss_fn,
            fed.local_steps,
            fed.local_lr,
            fed.prox_mu if fed.algorithm == "fedprox" else 0.0,
        )
        loss = float(loss)
        if thgs is not None:
            ks = schedules.leaf_ks(
                thgs,
                [x.size for x in leaves],
                t=state.round,
                total_rounds=fed.rounds,
                loss_prev=state.losses.get(c),
                loss_curr=loss,
            )
            streams, new_res = encode_update(
                delta, state.residuals[c], ks, thgs, sa,
                client=c, participants=participants, round_t=state.round,
            )
            streams_all[c] = streams
            state.residuals[c] = new_res
        else:
            deltas[c] = delta
        state.losses[c] = loss

    if thgs is not None:
        agg_leaves = aggregate_streams(
            [streams_all[c] for c in participants], leaf_shapes, leaf_dtypes
        )
        agg = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state.params), agg_leaves
        )
        ks_acct = [s.k for s in streams_all[participants[0]]]
        rec = CommRecord(
            round=state.round,
            upload_bits=len(participants) * bits.sparse_bits(sum(ks_acct)),
            download_bits=len(participants) * bits.dense_bits(model_size),
            dense_upload_bits=len(participants) * bits.dense_bits(model_size),
            n_clients=len(participants),
        )
    else:
        if sa.enabled:
            from repro.core.secure_agg import dense_masked_update

            masked = []
            for c in participants:
                leaves_c = jax.tree_util.tree_leaves(deltas[c])
                masked.append([
                    dense_masked_update(x, sa, c, participants, state.round, i)
                    for i, x in enumerate(leaves_c)
                ])
            summed = [
                sum(m[i] for m in masked) / len(participants)
                for i in range(len(leaves))
            ]
            agg = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(state.params),
                [s.astype(d) for s, d in zip(summed, leaf_dtypes)],
            )
        else:
            agg = jax.tree_util.tree_map(
                lambda *xs: sum(xs) / len(xs), *[deltas[c] for c in participants]
            )
        rec = CommRecord(
            round=state.round,
            upload_bits=len(participants) * bits.dense_bits(model_size),
            download_bits=len(participants) * bits.dense_bits(model_size),
            dense_upload_bits=len(participants) * bits.dense_bits(model_size),
            n_clients=len(participants),
        )

    state.params = jax.tree_util.tree_map(
        lambda p, d: p + fed.server_lr * d, state.params, agg
    )
    state.comm_log.append(rec)
    state.round += 1
    return state
