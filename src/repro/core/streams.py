"""The unified sparse-stream engine (paper Alg. 1/2, Eq. 5) — batched + jitted.

This module is the ONE implementation of the THGS ``top-k ∪ mask-support``
unified-stream encode and of the server-side scatter-add decode (DESIGN.md §3).
Every consumer — the single-host server (core/fedavg.py via core/secure_agg.py),
both datacenter step builders (launch/train.py), the blocked helpers
(core/blocked.py) and the examples — delegates here.

Data model
----------
A *stream* for one leaf is a static-shape pair ``(indices, values)``:

    indices : int32[..., n_blocks, k_total]   global indices row*m + col into the
                                              padded [n_blocks, m] block view
    values  : f32  [..., n_blocks, k_total]   w·acc[idx]·first_occurrence + mask

with a leading client axis when batched. ``n_blocks == 1, m == size`` recovers
the flat per-leaf stream of the paper's single-host protocol; ``n_blocks > 1``
is the device-aligned blocked layout of the datacenter path (core/blocked.py).

Encode is ``vmap``'d over the client axis and ``jit``'d end-to-end: one XLA
program encodes *all* clients of a round, replacing the per-client Python loop
of the seed implementation. Decode flattens every client's (weighted, liveness-
gated) stream into one index/value vector and scatter-adds it in a single pass
over the dense buffer — on TPU through the fused Pallas kernel
(kernels/stream_decode.py), elsewhere through XLA's native scatter.

Secure-aggregation semantics
----------------------------
Pairwise masks follow core/masks.py exactly. The default data plane is
**counter-based**: per-pair uint32 seeds (``pair_seed_matrix``, DH-derived in
masks.py / reconstructed via Shamir shares in repro/secagg) drive the murmur
streams of ``kernels/ref.pair_mask_stream_ref`` — Pallas twin
``kernels/mask_prng.pair_mask_streams`` on TPU — generating every client's
pair masks for a leaf in ONE fused pass (``mask_streams_all_pairs``), instead
of the per-pair host loop of the seed implementation. The legacy jax.random
path (``pair_key_matrix``/``pairwise_mask_rows``) remains for the in-trace
fold-key variants the datacenter shard_map step uses. Client weights are
applied to the *gradient* part of the values only — client-side, before
masking — so non-uniform weighted aggregation keeps mask cancellation exact
(server-side weighting would scale each endpoint's mask differently).
Dropout recovery is Bonawitz-style: the server regenerates every
survivor→dropped pair mask — from Shamir-reconstructed seeds
(``dropout_cancel_streams_seeded``, the repro/secagg protocol path) or from
the legacy pair keys (``dropout_cancel_streams``) — and subtracts it, so the
aggregate over survivors equals the unmasked weighted sparse sum.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# the codec x secagg rejection lives in ONE place (repro.lint RPL003)
from repro.core.codecs import reject_codec_with_masks

# The mesh axis name the client-parallel round shards over. Defined here (not
# in launch/mesh.py) because core must not import launch; the mesh builders in
# launch/mesh.py import this constant.
CLIENT_AXIS = "clients"


class StreamBatch(NamedTuple):
    """Stacked unified streams: leading axis = clients (absent when single)."""

    indices: jax.Array  # int32[..., n_blocks, k_total]
    values: jax.Array   # f32  [..., n_blocks, k_total]

    @property
    def k_total(self) -> int:
        return self.indices.shape[-1]


# --------------------------------------------------------------------- layout
def block_layout(size: int, n_blocks: int) -> tuple[int, int, int]:
    """(n_blocks, block_len, padded) — small leaves collapse to one block."""
    if size < 4 * n_blocks:
        n_blocks = 1
    m = -(-size // n_blocks)
    return n_blocks, m, n_blocks * m


def to_blocks(x: jax.Array, n_blocks: int, m: int) -> jax.Array:
    """Flat/leaf tensor -> padded [n_blocks, m] row-major block view."""
    flat = x.reshape(-1)
    pad = n_blocks * m - flat.shape[0]
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(n_blocks, m)


def from_blocks(blocks: jax.Array, size: int, shape: tuple) -> jax.Array:
    return blocks.reshape(-1)[:size].reshape(shape)


# ------------------------------------------------------- first-occurrence gate
def first_occurrence_rows(idx: jax.Array) -> jax.Array:
    """Per-row boolean: True iff the slot is the first occurrence of its index.

    Sort-based O(k log k) per row; duplicates of an index occupy consecutive
    ranks after sorting, so a slot is first iff its sorted predecessor differs.
    """
    order = jnp.argsort(idx, axis=-1)
    sorted_idx = jnp.take_along_axis(idx, order, -1)
    is_first = jnp.concatenate(
        [jnp.ones_like(sorted_idx[..., :1], bool),
         sorted_idx[..., 1:] != sorted_idx[..., :-1]], -1)
    out = jnp.zeros_like(is_first)
    rows = jnp.arange(idx.shape[0])[:, None]
    return out.at[rows, order].set(is_first)


# ------------------------------------------------------------- selector stage
def select_topk_rows(acc: jax.Array, k: int, selector: str,
                     sample_frac: float) -> jax.Array:
    """[n_blocks, m] -> int32[n_blocks, k] per-row top-|.| indices."""
    abs_acc = jnp.abs(acc)
    if selector == "sampled":
        from repro.core.sparsify import _sampled_topk

        _, idx = jax.vmap(lambda r: _sampled_topk(r, k, sample_frac))(abs_acc)
    else:  # 'exact' and 'local' (the caller pre-blocks for 'local')
        _, idx = jax.lax.top_k(abs_acc, k)
    return idx.astype(jnp.int32)


# ----------------------------------------------------- THE unified-stream core
def unified_stream_rows(
    acc: jax.Array,            # f32[n_blocks, m] error-feedback accumulator
    k: int,
    mask_idx: jax.Array | None,    # int32[n_blocks, k_mask_total] | None
    mask_vals: jax.Array | None,   # f32  [n_blocks, k_mask_total] | None
    *,
    selector: str = "exact",
    sample_frac: float = 0.01,
    weight: jax.Array | float = 1.0,
    dp_support: jax.Array | None = None,  # int32[n_blocks, k] public support
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One client, one leaf: ``top-k(|acc|) ∪ support(mask)`` unified stream.

    This is the single implementation of the paper's Eq. 5 encode (Alg. 2
    lines 10-17). Returns ``(idx, vals, new_acc)`` where ``idx`` is the local
    per-row column index, ``vals = weight·acc[idx]·first_occurrence + mask``
    (duplicate indices transmit the gradient once; mask values ride in their
    dedicated slots), and ``new_acc`` zeroes every transmitted position —
    including mask-support positions below the top-k threshold.

    ``dp_support`` switches the stream into its DP release shape (core/dp.py,
    DESIGN.md §15): the k data slots release the *public common support*
    instead of the data-dependent top-k (the transmitted indices leak
    nothing), mask slots carry masks ONLY (no gradient values ride them),
    and ``new_acc`` zeroes only the released support positions — everything
    else stays in the error-feedback residual.
    """
    nb, m = acc.shape
    k = int(min(k, m))
    if dp_support is not None:
        idx_t = dp_support
    else:
        idx_t = select_topk_rows(acc, k, selector, sample_frac)
    if mask_idx is not None and mask_idx.shape[-1] > 0:
        idx = jnp.concatenate([idx_t, mask_idx], -1)
        mvals = jnp.concatenate(
            [jnp.zeros((nb, k), jnp.float32), mask_vals], -1)
    else:
        idx = idx_t
        mvals = jnp.zeros((nb, k), jnp.float32)

    first = first_occurrence_rows(idx)
    if dp_support is not None:
        # DP: gradient values are released on the support slots alone; a mask
        # slot that happens to be the first occurrence of its index must not
        # smuggle the (un-noised) gradient value out beside the masks
        data_slot = jnp.concatenate(
            [jnp.ones((nb, k), bool),
             jnp.zeros((nb, idx.shape[-1] - k), bool)], -1)
        first = first & data_slot
    gvals = jnp.take_along_axis(acc, idx, -1)
    vals = weight * gvals * first.astype(acc.dtype) + mvals
    rows = jnp.arange(nb)[:, None]
    new_acc = acc.at[rows, idx_t if dp_support is not None else idx].set(0.0)
    return idx, vals, new_acc


# ------------------------------------------------------------- pairwise masks
def pair_key_matrix(sa, participant_ids: Sequence[int], round_t: int):
    """Host-side [C, C] pair keys + signs from the DH-agreed pair secrets.

    ``keys[i, j]`` is ``masks.pair_key(sa, ids[i], ids[j], round_t)`` (folded
    with the leaf id inside the encode); ``signs[i, j]`` is +1 when
    ids[i] < ids[j], -1 when >, and 0 on the diagonal (self pair inactive).
    Both endpoints of a pair hold identical keys, so the generated masks cancel
    in the aggregate — and the server can regenerate them for dropout recovery.
    """
    from repro.core.masks import pair_key

    ids = list(participant_ids)
    n = len(ids)
    keys = [[pair_key(sa, ids[i], ids[j], round_t)
             for j in range(n)] for i in range(n)]
    keys = jnp.stack([jnp.stack(row) for row in keys])
    signs = jnp.array(
        [[0.0 if i == j else (1.0 if ids[i] < ids[j] else -1.0)
          for j in range(n)] for i in range(n)], jnp.float32)
    return keys, signs


def pair_seed_matrix(sa, participant_ids: Sequence[int], round_t: int):
    """Host-side [C, C] uint32 counter seeds + signs for the round's pairs.

    ``seeds[i, j]`` is ``masks.pair_seed(sa, ids[i], ids[j], round_t)`` — the
    DH-agreed pair secret hashed with the round, identical from both ends, so
    the counter-based mask streams cancel in the aggregate. The diagonal
    (self pair) is seed 0 with sign 0; its slots are value-gated to zero and
    support-gated onto the block's top-1 index by the encode. This is what
    the repro/secagg round protocol hands the data plane; the server re-derives
    exactly these seeds for dropped clients from their Shamir shares.
    """
    from repro.core import masks

    ids = list(participant_ids)
    # one key derivation per participant and one modexp per unordered pair
    # (the seed is symmetric), not per matrix entry — at paper-scale cohorts
    # the per-entry sha256+modexp re-derivation dominates round setup
    privs = [masks.dh_private(sa.seed, u) for u in ids]
    pubs = [masks.dh_public(x) for x in privs]
    return masks.seed_matrix_from_keys(ids, privs, pubs, round_t)


def _fold_seeds(seeds: jax.Array, leaf_id) -> jax.Array:
    from repro.kernels import ref as kref

    seeds = jnp.asarray(seeds, jnp.uint32)
    return kref.fold_leaf_seed(seeds, leaf_id) if leaf_id is not None \
        else seeds


def _client_mask_layout(idx: jax.Array, mag: jax.Array, signs: jax.Array,
                        nb: int, k_mask: int) -> tuple[jax.Array, jax.Array]:
    """``[Cr, C, nb, k_mask]`` pair streams -> the engine's per-client layout
    ``[Cr, nb, C * k_mask]`` (peer-major within a row), signs applied to the
    magnitudes. Shared by the full-matrix and row-slice generators so the
    serial and sharded encodes can never disagree on the slot layout."""
    cr, n = idx.shape[:2]
    vals = jnp.asarray(signs, jnp.float32)[:, :, None, None] * mag
    idx = idx.transpose(0, 2, 1, 3)
    vals = vals.transpose(0, 2, 1, 3)
    return idx.reshape(cr, nb, n * k_mask), vals.reshape(cr, nb, n * k_mask)


def mask_streams_all_pairs(
    pair_seeds: jax.Array,   # uint32[C, C] counter seeds (0 on the diagonal)
    pair_signs: jax.Array,   # f32[C, C] Bonawitz signs (0 on the diagonal)
    nb: int,
    k_mask: int,
    m: int,
    *,
    p: float,
    q: float,
    leaf_id: int | jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Every client's concatenated pair-mask streams in ONE fused pass.

    Counter-based data plane: all C*C pair streams are generated by a single
    kernel/oracle dispatch (kernels/ops.pair_mask_streams) and reshaped to the
    engine's per-client layout ``[C, nb, C * k_mask]`` (peer-major within a
    row, self slot included — the encode gates it). Replaces the per-pair
    host loop of masks.client_masks on the batched path.
    """
    from repro.kernels import ops

    C = pair_seeds.shape[0]
    seeds = _fold_seeds(pair_seeds, leaf_id)
    # the seed matrix is symmetric and a stream's idx/|val| depend only on
    # the seed, so generate each unordered pair (upper triangle incl. the
    # diagonal) once and mirror via a static gather — halving the mask-PRNG
    # work of the per-leaf hot path. Signs are applied outside the
    # generator (sign * (p + q*u), exact for sign in {-1, 0, +1}), so the
    # mirrored copy is the bit-exact negation the cancellation needs.
    iu, ju = np.triu_indices(C)
    tri = np.zeros((C, C), np.int64)
    tri[iu, ju] = np.arange(len(iu))
    tri[ju, iu] = tri[iu, ju]
    idx_u, mag_u = ops.pair_mask_streams(
        seeds[iu, ju], jnp.ones((len(iu),), jnp.float32),
        nb=nb, k_mask=k_mask, m=m, p=p, q=q)
    return _client_mask_layout(idx_u[tri], mag_u[tri], pair_signs, nb, k_mask)


def mask_streams_rows(
    seeds_rows: jax.Array,   # uint32[C_loc, C] this shard's rows of the matrix
    signs_rows: jax.Array,   # f32[C_loc, C] matching sign rows
    nb: int,
    k_mask: int,
    m: int,
    *,
    p: float,
    q: float,
    leaf_id: int | jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """A row-slice of ``mask_streams_all_pairs`` for the client-sharded round.

    Inside the shard_map each device holds ``C_loc = C / n_dev`` clients and
    generates only their pair-mask streams from the corresponding rows of the
    (replicated) seed/sign matrices. A stream's idx/|val| depend only on the
    seed and the seed matrix is symmetric, so row-wise generation is bit-exact
    with the triangle-mirrored full-matrix pass the serial path uses — the
    parity tests pin this. Returns the engine's per-client layout
    ``(idx int32[C_loc, nb, C*k_mask], vals f32[C_loc, nb, C*k_mask])``.
    """
    from repro.kernels import ops

    c_loc, n = seeds_rows.shape
    seeds = _fold_seeds(seeds_rows, leaf_id).reshape(c_loc * n)
    idx, mag = ops.pair_mask_streams(
        seeds, jnp.ones((c_loc * n,), jnp.float32),
        nb=nb, k_mask=k_mask, m=m, p=p, q=q)
    return _client_mask_layout(idx.reshape(c_loc, n, nb, k_mask),
                               mag.reshape(c_loc, n, nb, k_mask),
                               signs_rows, nb, k_mask)


def fold_pair_key_matrix(mask_key: jax.Array, n: int):
    """In-trace [n, n] pair keys + signs for positional participants 0..n-1.

    The datacenter path has no host-side client ids (participants are mesh
    positions); the pair secret is a fold_in chain of the round key over the
    unordered pair — both endpoints derive the same key, as with dh_agree.
    """
    keys = [[jax.random.fold_in(jax.random.fold_in(mask_key, min(i, j)),
                                max(i, j))
             for j in range(n)] for i in range(n)]
    keys = jnp.stack([jnp.stack(row) for row in keys])
    signs = jnp.array(
        [[0.0 if i == j else (1.0 if i < j else -1.0) for j in range(n)]
         for i in range(n)], jnp.float32)
    return keys, signs


def fold_pair_keys_row(mask_key: jax.Array, self_id: jax.Array, n: int):
    """One participant's row of fold_in pair keys/signs, for traced self_id
    (the shard_map path, where self_id = lax.axis_index). Matches
    ``fold_pair_key_matrix(mask_key, n)[self_id]``."""
    keys, signs = [], []
    for peer in range(n):
        lo = jnp.minimum(self_id, peer)
        hi = jnp.maximum(self_id, peer)
        keys.append(jax.random.fold_in(jax.random.fold_in(mask_key, lo), hi))
        signs.append(jnp.where(self_id < peer, 1.0, -1.0)
                     * (self_id != peer).astype(jnp.float32))
    return jnp.stack(keys), jnp.stack(signs)


def pairwise_mask_rows(
    pair_keys_row: jax.Array,   # [n_peers] typed keys (this client's row)
    signs_row: jax.Array,       # f32[n_peers], 0 for the self slot
    nb: int,
    k_mask: int,
    m: int,
    *,
    p: float,
    q: float,
    leaf_id: int | jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One client's concatenated mask support/values over all peers.

    Per peer: ``k_mask`` pseudo-random positions per block in [0, m) and
    uniform magnitudes in [p, p+q), signed by the Bonawitz convention.
    For ``nb == 1`` this reproduces ``masks.pair_mask`` draw-for-draw.
    Returns (idx int32[nb, n_peers*k_mask], vals f32[nb, n_peers*k_mask]).
    """
    n_peers = pair_keys_row.shape[0]

    def one_peer(pk, sign):
        if leaf_id is not None:
            pk = jax.random.fold_in(pk, leaf_id)
        k_i, k_v = jax.random.split(pk)
        pidx = jax.random.randint(k_i, (nb, k_mask), 0, m, dtype=jnp.int32)
        pval = jax.random.uniform(k_v, (nb, k_mask), minval=p, maxval=p + q,
                                  dtype=jnp.float32)
        return pidx, sign * pval

    pidx, pval = jax.vmap(one_peer)(pair_keys_row, signs_row)  # [n_peers,nb,km]
    idx = jnp.moveaxis(pidx, 0, 1).reshape(nb, n_peers * k_mask)
    vals = jnp.moveaxis(pval, 0, 1).reshape(nb, n_peers * k_mask)
    return idx, vals


# ------------------------------------------------------------- batched encode
def encode_client_blocks(
    acc: jax.Array,             # f32[nb, m] one client's accumulator
    k: int,
    *,
    selector: str = "exact",
    sample_frac: float = 0.01,
    pair_keys_row: jax.Array | None = None,   # [n_peers] typed keys
    pair_signs_row: jax.Array | None = None,  # f32[n_peers], 0 = self slot
    mask_idx: jax.Array | None = None,   # precomputed int32[nb, n_peers*k_mask]
    mask_vals: jax.Array | None = None,  # precomputed f32 (counter-based path)
    k_mask: int = 0,
    mask_p: float = -1.0,
    mask_q: float = 2.0,
    leaf_id: int | jax.Array | None = None,
    weight: jax.Array | float = 1.0,
    dp_support: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One client's full encode: pairwise masks + unified stream, block view.

    Mask support arrives either precomputed (``mask_idx``/``mask_vals`` from
    the fused counter-based pass, plus ``pair_signs_row`` for the self gate)
    or is generated here from legacy jax.random pair keys. Returns
    (global_idx int32[nb, k_total], vals, new_acc). ``global_idx`` is
    ``row*m + col`` — flat into the padded block space (equals the flat leaf
    index when nb == 1). vmap-polymorphic: both the batched entry below and the
    shard_map datacenter path (traced self_id) call this. ``dp_support``
    switches the data slots onto the round's public common support
    (``unified_stream_rows``; core/dp.py).
    """
    nb, m = acc.shape
    if mask_idx is not None and k_mask > 0:
        m_idx, m_vals = mask_idx, mask_vals
    elif pair_keys_row is not None and k_mask > 0:
        m_idx, m_vals = pairwise_mask_rows(
            pair_keys_row, pair_signs_row, nb, k_mask, m,
            p=mask_p, q=mask_q, leaf_id=leaf_id)
    else:
        m_idx = m_vals = None
    if m_idx is not None and dp_support is None:
        # Inactive (self) slots carry zero mask value; point their support
        # at the block's top-1 position so first-occurrence gating zeroes
        # the slot entirely — a random support index there would transmit
        # the raw gradient unmasked.
        top1 = jnp.argmax(jnp.abs(acc), -1).astype(jnp.int32)[:, None]
        col_active = jnp.repeat(pair_signs_row != 0.0, k_mask)[None, :]
        m_idx = jnp.where(col_active, m_idx, top1)
    # Under DP (dp_support set) mask slots carry no gradient values at all,
    # so the self slot is silent at its raw counter-drawn index already — and
    # the top-1 override above would leak argmax(|acc|) through a transmitted
    # index, which the public-support release exists to prevent.
    idx, vals, new_acc = unified_stream_rows(
        acc, k, m_idx, m_vals, selector=selector,
        sample_frac=sample_frac, weight=weight, dp_support=dp_support)
    rows = jnp.arange(nb, dtype=jnp.int32)[:, None]
    return (rows * m + idx).astype(jnp.int32), vals, new_acc


def encode_batch_blocks(
    acc: jax.Array,             # f32[C, nb, m] stacked accumulators
    k: int,
    *,
    selector: str = "exact",
    sample_frac: float = 0.01,
    pair_keys: jax.Array | None = None,   # [C, C] typed keys (legacy path)
    pair_signs: jax.Array | None = None,  # f32[C, C]
    pair_seeds: jax.Array | None = None,  # uint32[C, C] counter seeds
    k_mask: int = 0,
    mask_p: float = -1.0,
    mask_q: float = 2.0,
    leaf_id: int | jax.Array | None = None,
    weights: jax.Array | None = None,     # f32[C] client-side gradient weights
    dp_support: jax.Array | None = None,  # int32[nb, k] public common support
) -> tuple[StreamBatch, jax.Array]:
    """Batched client encode: all clients of a round in one vmapped program.

    With ``pair_seeds`` (the repro/secagg protocol path) every pair mask of
    the round is generated counter-based in one fused pass *before* the vmap
    (``mask_streams_all_pairs``); ``pair_keys`` selects the legacy jax.random
    per-client generation instead. Returns (StreamBatch with *global* indices
    row*m + col, new_acc [C, nb, m]). The caller owns the block view
    (``to_blocks``/``from_blocks`` or the sharding-aligned transform of
    core/blocked.py) and the error-feedback accumulate ``acc = residual +
    update``. ``dp_support`` (one support, shared by every client — that is
    the point) routes the encode through the DP release shape (core/dp.py).
    """
    C, nb, m = acc.shape
    if weights is None:
        weights = jnp.ones((C,), jnp.float32)
    use_seeds = pair_seeds is not None and k_mask > 0 and C >= 2
    use_keys = (not use_seeds and pair_keys is not None and k_mask > 0
                and C >= 2)

    if use_seeds:
        m_idx, m_vals = mask_streams_all_pairs(
            pair_seeds, pair_signs, nb, k_mask, m,
            p=mask_p, q=mask_q, leaf_id=leaf_id)

        def one_seeded(acc_c, m_idx_c, m_vals_c, signs_row, w_c):
            return encode_client_blocks(
                acc_c, k, selector=selector, sample_frac=sample_frac,
                mask_idx=m_idx_c, mask_vals=m_vals_c,
                pair_signs_row=signs_row, k_mask=k_mask,
                mask_p=mask_p, mask_q=mask_q, weight=w_c,
                dp_support=dp_support)

        gidx, vals, new_acc = jax.vmap(one_seeded)(
            acc, m_idx, m_vals, pair_signs, weights)
        return StreamBatch(indices=gidx, values=vals), new_acc

    def one_client(acc_c, keys_row, signs_row, w_c):
        return encode_client_blocks(
            acc_c, k, selector=selector, sample_frac=sample_frac,
            pair_keys_row=keys_row, pair_signs_row=signs_row,
            k_mask=k_mask if use_keys else 0, mask_p=mask_p, mask_q=mask_q,
            leaf_id=leaf_id, weight=w_c, dp_support=dp_support)

    if use_keys:
        gidx, vals, new_acc = jax.vmap(one_client)(
            acc, pair_keys, pair_signs, weights)
    else:
        gidx, vals, new_acc = jax.vmap(
            lambda a, w: one_client(a, None, None, w))(acc, weights)
    return StreamBatch(indices=gidx, values=vals), new_acc


# ----------------------------------------------------- wire-format codec stage
def codec_wire_stage(gidx, vals, new_acc, weights, m: int, codec: str):
    """The client-side StreamCodec stage (DESIGN.md §12), mask-free rounds only.

    Quantizes the batched stream values row-wise, absorbs the quantization
    error into the error-feedback accumulator (transmitted positions were just
    zeroed by ``unified_stream_rows``; they now carry ``(sent - wire)/weight``
    so the error re-enters next round's accumulator and accuracy doesn't
    drift), and sorts each block row by column for the delta-packed index
    wire. Returns ``(cols int32[C, nb, k] sorted, q int32[C, nb, k],
    scales f32[C, nb], new_acc)``.
    """
    from repro.core import codecs

    C = gidx.shape[0]
    nb = gidx.shape[1]
    w = (jnp.asarray(weights, jnp.float32) if weights is not None
         else jnp.ones((C,), jnp.float32))
    q, scales = codecs.quantize_rows(vals, codec)
    vq = codecs.dequantize_rows(q, scales)
    cols = gidx % m
    err = (vals - vq) / jnp.where(w == 0.0, 1.0, w)[:, None, None]
    rows = jnp.arange(nb)[:, None]
    new_acc = jax.vmap(lambda a, c2, e: a.at[rows, c2].add(e))(
        new_acc, cols, err)
    order = jnp.argsort(cols, axis=-1)
    cols_s = jnp.take_along_axis(cols, order, -1)
    q_s = jnp.take_along_axis(q, order, -1)
    return cols_s, q_s, scales, new_acc


def codec_wire_roundtrip(cols_s, q_s, scales, m: int, codec: str):
    """Physically pack -> unpack -> dequantize one batched stream, so every
    round exercises the exact uint32 word wire (kernels/pack.py). The round
    trip is lossless: same sorted cols back, values on the quantization
    lattice. Returns ``(cols int32[C, nb, k], vq f32[C, nb, k])``."""
    from repro.core import codecs

    iw, vw = codecs.pack_stream_rows(cols_s, q_s, m=m, codec=codec)
    cols2, q2 = codecs.unpack_stream_rows(iw, vw, k=q_s.shape[-1], m=m,
                                          codec=codec)
    return cols2, codecs.dequantize_rows(q2, scales)




@functools.partial(
    jax.jit,
    static_argnames=("k", "nb", "m", "size", "selector", "sample_frac",
                     "k_mask", "mask_p", "mask_q", "codec", "dp_sigma"))
def encode_leaf_batch(
    updates: jax.Array,        # [C, *leaf_shape] stacked client updates
    residuals: jax.Array,      # [C, *leaf_shape] stacked error feedback
    *,
    k: int,
    nb: int,
    m: int,
    size: int,
    selector: str = "exact",
    sample_frac: float = 0.01,
    pair_keys: jax.Array | None = None,
    pair_signs: jax.Array | None = None,
    pair_seeds: jax.Array | None = None,
    k_mask: int = 0,
    mask_p: float = -1.0,
    mask_q: float = 2.0,
    leaf_id: int | jax.Array = 0,
    weights: jax.Array | None = None,
    codec: str = "f32",
    dp_sigma: float = 0.0,
    dp_seeds: jax.Array | None = None,
    dp_support_seed: jax.Array | int = 0,
) -> tuple[StreamBatch, jax.Array]:
    """Jitted leaf-level encode: accumulate -> block view -> batched encode.

    The single entry point the reference server (core/fedavg.py) uses per
    leaf and round. One compiled program per (leaf shape, ``k``, ``k_mask``)
    covers every client — this replaced the seed's serial per-client
    ``encode_update`` loop. ``leaf_id`` is traced (it only feeds ``fold_in``),
    so same-shaped leaves share one executable; the time-varying ``k``
    schedule is the only remaining re-specialization source (quantized by
    ``THGSConfig.k_levels`` — see DESIGN.md §9 for the sim engine's
    compile-once contract).

    Parameters
    ----------
    updates : f32-castable[C, *leaf_shape]
        Stacked client updates (local model deltas) for one leaf.
    residuals : [C, *leaf_shape]
        Stacked per-client error-feedback accumulators; the encode operates
        on ``residuals + updates``.
    k : int
        Top-k slots per block (static; one value serves all clients).
    nb, m, size : int
        Block layout: ``nb`` blocks of length ``m`` covering the ``size``
        -element leaf (``nb == 1, m == size`` is the flat single-host
        protocol; see ``block_layout``).
    selector : {'exact', 'sampled', 'local'}
        Top-k selector (THGSConfig.selector).
    sample_frac : float
        Subsample fraction for ``selector='sampled'``.
    pair_keys, pair_signs : [C, C] typed keys / f32[C, C], optional
        Legacy jax.random pairwise-mask key matrix and Bonawitz signs from
        ``pair_key_matrix``; ``None`` encodes without secure aggregation.
    pair_seeds : uint32[C, C], optional
        Counter-based pair seeds from ``pair_seed_matrix`` (the repro/secagg
        protocol path); takes precedence over ``pair_keys`` and routes mask
        generation through the fused kernel/oracle data plane.
    k_mask : int
        Mask-support slots per pair per block (Eq. 4); 0 disables masking.
    mask_p, mask_q : float
        Uniform mask support ``[p, p + q)`` (paper §3.2).
    leaf_id : int or traced int
        Folded into every pair key so leaves draw independent masks.
    weights : f32[C], optional
        Client-side aggregation weights applied to the gradient values
        *before* masking (module docstring); None means uniform.
    codec : {'f32', 'int8', 'int4', '1bit'}
        Stream value wire codec (core/codecs.py, DESIGN.md §12). Non-f32
        codecs quantize the values (error absorbed into the returned
        residuals), sort + delta-pack the indices, and run the packed wire
        round trip in-trace; they require ``k_mask == 0`` — pair masks cancel
        only on the f32 grid.
    dp_sigma : float (static)
        Per-client DP noise stddev (``DPConfig.sigma_client``); > 0 switches
        the encode into its DP release shape (core/dp.py, DESIGN.md §15):
        the k data slots release the round's PUBLIC common support instead
        of the data-dependent top-k, mask slots carry masks only, and
        grid-exact Gaussian noise is added to every released slot under the
        pair masks. 0 statically skips the stage, so DP-off rounds are
        bit-identical to pre-DP rounds. Requires the f32 codec, ``dp_seeds``
        and ``dp_support_seed``.
    dp_seeds : uint32[C], optional
        Per-(round, client) noise-stream seeds (``DPConfig.client_seeds``),
        folded with ``leaf_id`` in-trace like the pair seeds.
    dp_support_seed : uint32 scalar
        The round's common-support seed (``DPConfig.support_seed``) — a pure
        function of (dp seed, round), shared by the cohort; folded with
        ``leaf_id`` in-trace. Only read when ``dp_sigma > 0``.

    Returns
    -------
    streams : StreamBatch
        ``indices`` int32[C, nb, k_total] global (``row*m + col``) indices and
        ``values`` f32[C, nb, k_total], where ``k_total = k + C*k_mask``
        (the gated self-pair slot is never counted on the wire — Eq. 6
        accounting uses ``k + (C-1)*k_mask``).
    new_residuals : [C, *leaf_shape]
        Updated error feedback: transmitted positions zeroed, same dtype as
        ``residuals``.
    """
    leaf_shape = updates.shape[1:]
    reject_codec_with_masks(codec, k_mask)
    dp_on = dp_sigma > 0.0
    dp_support = None
    if dp_on:
        from repro.core import dp as dp_mod

        dp_mod.reject_codec_with_noise(codec, dp_sigma)
        if dp_seeds is None:
            raise ValueError("dp_sigma > 0 requires dp_seeds")
        dp_support = dp_mod.common_support(
            dp_support_seed, nb, min(int(k), m), m, leaf_id)
    acc = jax.vmap(lambda u, r: to_blocks(
        r.astype(jnp.float32) + u.astype(jnp.float32), nb, m))(
            updates, residuals)
    streams, new_acc = encode_batch_blocks(
        acc, k, selector=selector, sample_frac=sample_frac,
        pair_keys=pair_keys, pair_signs=pair_signs, pair_seeds=pair_seeds,
        k_mask=k_mask, mask_p=mask_p, mask_q=mask_q, leaf_id=leaf_id,
        weights=weights, dp_support=dp_support)
    if dp_on:
        streams = StreamBatch(
            indices=streams.indices,
            values=dp_mod.add_stream_noise(
                streams.values, dp_seeds, sigma=dp_sigma, leaf_id=leaf_id,
                k_data=min(int(k), m)))
    if codec != "f32":
        cols, q, scales, new_acc = codec_wire_stage(
            streams.indices, streams.values, new_acc, weights, m, codec)
        cols, vq = codec_wire_roundtrip(cols, q, scales, m, codec)
        rows_b = jnp.arange(nb, dtype=jnp.int32)[None, :, None]
        streams = StreamBatch(indices=(rows_b * m + cols).astype(jnp.int32),
                              values=vq)
    new_res = jax.vmap(lambda b: from_blocks(b, size, leaf_shape))(new_acc)
    return streams, new_res.astype(residuals.dtype)


# ------------------------------------------------------------- server decode
def _scatter_flat(flat_idx: jax.Array, flat_vals: jax.Array,
                  padded: int, use_pallas: bool) -> jax.Array:
    if use_pallas:
        from repro.kernels import ops

        return ops.stream_scatter_add(flat_idx, flat_vals, size=padded)
    return jnp.zeros((padded,), jnp.float32).at[flat_idx].add(flat_vals)


def _flatten_round_stream(
    streams: StreamBatch,
    alive: jax.Array | None,
    weights: jax.Array | None,
    extra: StreamBatch | None,
) -> tuple[jax.Array, jax.Array]:
    """The round's single flat (idx, vals) stream: per-client gating applied,
    recovery streams appended. Shared by the flat and tree decodes so both
    topologies fold the *identical* slot sequence (DESIGN.md §13)."""
    C = streams.indices.shape[0]
    gate = jnp.ones((C,), jnp.float32)
    if weights is not None:
        gate = gate * jnp.asarray(weights, jnp.float32)
    if alive is not None:
        gate = gate * jnp.asarray(alive, jnp.float32)
    vals = streams.values * gate[:, None, None]
    flat_idx = streams.indices.reshape(-1)
    flat_vals = vals.reshape(-1)
    if extra is not None:
        flat_idx = jnp.concatenate([flat_idx, extra.indices.reshape(-1)])
        flat_vals = jnp.concatenate(
            [flat_vals, extra.values.reshape(-1).astype(jnp.float32)])
    return flat_idx, flat_vals


def decode_sum_blocks(
    streams: StreamBatch,      # [C, nb, k_total] global indices/values
    nb: int,
    m: int,
    *,
    alive: jax.Array | None = None,      # bool/f32[C] survivor gate
    weights: jax.Array | None = None,    # f32[C] server-side weights (uniform
                                         # protocols only — see module doc)
    extra: StreamBatch | None = None,    # reconstruction streams, weight 1
    use_pallas: bool | None = None,
) -> jax.Array:
    """Scatter-add every client's stream into the dense [nb*m] buffer — one
    fused pass (Pallas on TPU, XLA scatter elsewhere). Returns f32[nb*m]."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    flat_idx, flat_vals = _flatten_round_stream(streams, alive, weights,
                                                extra)
    return _scatter_flat(flat_idx, flat_vals, nb * m, use_pallas)


# ------------------------------------------- hierarchical (tree) decode (§13)
def tree_splits(padded: int, n_groups: int) -> tuple[int, ...]:
    """Near-even contiguous index-range boundaries for ``n_groups``
    sub-aggregators over a ``padded``-element dense buffer.

    Returns ``G + 1`` monotone boundaries ``(0, ..., padded)``; group ``g``
    owns ``[splits[g], splits[g+1])``. ``n_groups`` is clamped to
    ``[1, padded]`` (a group must own at least one position). Any monotone
    boundary tuple is a valid partition for :func:`decode_sum_tree` — the
    property suite exercises arbitrary uneven ones.
    """
    G = max(1, min(int(n_groups), int(padded)))
    base, rem = divmod(int(padded), G)
    bounds = [0]
    for g in range(G):
        bounds.append(bounds[-1] + base + (1 if g < rem else 0))
    return tuple(bounds)


def _scatter_range(flat_idx: jax.Array, flat_vals: jax.Array,
                   lo: int, hi: int, use_pallas: bool) -> jax.Array:
    """One sub-aggregator's partial: scatter the slots landing in
    ``[lo, hi)`` of the padded buffer, in the round stream's slot order.

    Out-of-range slots are redirected to a dump slot at position ``width``
    (buffer ``width + 1``, sliced off on return) with value 0.0 — NOT zeroed
    in place: an in-range position must never receive a redirected ``+0.0``
    (``-0.0 + 0.0 == +0.0`` would flip the sign bit of a ``-0.0`` partial
    and break bit-exactness with the flat scatter).
    """
    width = hi - lo
    in_range = (flat_idx >= lo) & (flat_idx < hi)
    local = jnp.where(in_range, flat_idx - lo, width)
    vals = jnp.where(in_range, flat_vals, 0.0)
    return _scatter_flat(local, vals, width + 1, use_pallas)[:width]


def decode_sum_tree(
    streams: StreamBatch,      # [C, nb, k_total] global indices/values
    nb: int,
    m: int,
    *,
    splits: Sequence[int],               # G + 1 boundaries (tree_splits)
    alive: jax.Array | None = None,      # bool/f32[C] survivor gate
    weights: jax.Array | None = None,    # f32[C] server-side weights
    extra: StreamBatch | None = None,    # reconstruction streams, weight 1
    use_pallas: bool | None = None,
) -> jax.Array:
    """Hierarchical decode: G sub-aggregators each scatter-add the round
    stream's slots landing in their contiguous index range of the dense
    buffer; the inter-group combine is pure concatenation. Returns f32[nb*m].

    Because each position of the buffer is owned by exactly one group and
    every group folds its positions' contributions in the same slot order as
    the flat decode, the result is **bit-exact** with
    :func:`decode_sum_blocks` for *any* partition — the combine performs zero
    floating-point additions (DESIGN.md §13; client-group dense partials
    would re-associate f32 sums and drift). Mask cancellation needs no
    protocol change: both endpoints of every pair mask target the same
    positions, so their slots route to the same sub-aggregator and cancel
    inside its partial.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    splits = tuple(int(s) for s in splits)
    if len(splits) < 2 or splits[0] != 0 or splits[-1] != nb * m or \
            any(b < a for a, b in zip(splits, splits[1:])):
        raise ValueError(
            f"splits must be monotone boundaries (0, ..., {nb * m}), "
            f"got {splits}")
    flat_idx, flat_vals = _flatten_round_stream(streams, alive, weights,
                                                extra)
    parts = [_scatter_range(flat_idx, flat_vals, lo, hi, use_pallas)
             for lo, hi in zip(splits[:-1], splits[1:]) if hi > lo]
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def dropout_cancel_streams(
    pair_keys: jax.Array,    # [C, C] typed keys (as used at encode time)
    pair_signs: jax.Array,   # f32[C, C]
    alive: jax.Array,        # bool[C]
    nb: int,
    k_mask: int,
    m: int,
    *,
    p: float,
    q: float,
    leaf_id: int | jax.Array | None = None,
) -> StreamBatch:
    """Bonawitz dropout recovery: regenerate every survivor→dropped pair mask
    and emit its negation, so the survivor sum's unpaired masks cancel.

    In the real protocol the server learns the pair secrets of dropped clients
    via Shamir shares; here it regenerates them from the same pair keys the
    encode used. Pairs are gated by ``alive[s] & ~alive[d]`` — survivor/survivor
    masks already cancel pairwise, dropped/dropped streams never arrived.
    """
    C = pair_keys.shape[0]
    alive_f = jnp.asarray(alive, jnp.float32)

    def one_pair(pk, sign, gate):
        idx, vals = pairwise_mask_rows(
            pk[None], sign[None], nb, k_mask, m, p=p, q=q, leaf_id=leaf_id)
        return idx, -gate * vals

    gates = alive_f[:, None] * (1.0 - alive_f[None, :])   # [C, C] s alive, d not
    flat_keys = pair_keys.reshape(C * C)
    flat_signs = pair_signs.reshape(C * C)
    flat_gates = gates.reshape(C * C)
    idx, vals = jax.vmap(one_pair)(flat_keys, flat_signs, flat_gates)
    idx = idx.reshape(C * C, nb, k_mask)
    # decode consumes GLOBAL indices (row*m + col); nb == 1 leaves this a
    # no-op, the blocked layout needs the row offset
    idx = jnp.arange(nb, dtype=jnp.int32)[None, :, None] * m + idx
    return StreamBatch(indices=idx,
                       values=vals.reshape(C * C, nb, k_mask))


def dropout_cancel_streams_seeded(
    pair_seeds: jax.Array,   # uint32[C, C] counter seeds (reconstructed or
                             # original — only survivor→dropped entries used)
    pair_signs: jax.Array,   # f32[C, C]
    alive: jax.Array,        # bool[C]
    nb: int,
    k_mask: int,
    m: int,
    *,
    p: float,
    q: float,
    leaf_id: int | jax.Array | None = None,
) -> StreamBatch:
    """Bonawitz dropout recovery on the counter-based data plane.

    Regenerates every survivor→dropped pair mask from the (Shamir-
    reconstructed) pair seeds in one fused pass and emits its negation; pairs
    outside the ``alive[s] & ~alive[d]`` gate contribute zero values, so a
    seed matrix filled only at the recovered entries is sufficient. Survivor/
    survivor masks already cancel pairwise, dropped/dropped streams never
    arrived. Bit-identical to the masks the encode applied — the property
    tests/test_secagg_protocol.py pins.
    """
    from repro.kernels import ops

    C = pair_seeds.shape[0]
    alive_f = jnp.asarray(alive, jnp.float32)
    seeds = _fold_seeds(pair_seeds, leaf_id).reshape(C * C)
    idx, vals = ops.pair_mask_streams(
        seeds, jnp.asarray(pair_signs, jnp.float32).reshape(C * C),
        nb=nb, k_mask=k_mask, m=m, p=p, q=q)
    gates = (alive_f[:, None] * (1.0 - alive_f[None, :])).reshape(C * C)
    vals = -gates[:, None, None] * vals
    idx = jnp.arange(nb, dtype=jnp.int32)[None, :, None] * m + idx
    return StreamBatch(indices=idx, values=vals)


@functools.partial(
    jax.jit,
    static_argnames=("nb", "m", "size", "k_mask", "mask_p", "mask_q",
                     "use_pallas"))
def decode_leaf_batch(
    streams: StreamBatch,
    *,
    nb: int,
    m: int,
    size: int,
    alive: jax.Array | None = None,
    weights: jax.Array | None = None,
    pair_keys: jax.Array | None = None,
    pair_signs: jax.Array | None = None,
    pair_seeds: jax.Array | None = None,
    k_mask: int = 0,
    mask_p: float = -1.0,
    mask_q: float = 2.0,
    leaf_id: int | jax.Array = 0,
    use_pallas: bool | None = None,
) -> jax.Array:
    """Jitted server decode for one leaf: survivor-gated fused scatter-add,
    plus reconstructed-mask cancellation when ``alive`` marks dropouts.

    Parameters
    ----------
    streams : StreamBatch
        All clients' unified streams for the leaf, as produced by
        ``encode_leaf_batch`` (global indices, leading client axis).
    nb, m, size : int
        Block layout the streams were encoded under; the dense buffer is
        ``nb * m`` padded elements, truncated to ``size`` on return.
    alive : bool[C], optional
        Survivor gate: False rows' streams are excluded (their upload never
        arrived). When given together with ``pair_seeds`` (or legacy
        ``pair_keys``) and ``k_mask``, the survivors' unpaired masks toward
        the dropped clients are regenerated and cancelled
        (``dropout_cancel_streams_seeded`` / ``dropout_cancel_streams`` —
        Bonawitz recovery). On the protocol path the seeds are the Shamir-
        reconstructed ones (repro/secagg), not the encode-time originals.
    weights : f32[C], optional
        Server-side per-stream scaling. Only correct for protocols whose
        masks cancel under it (uniform weighting); weighted FL applies
        weights client-side at encode time instead (module docstring).
    pair_keys, pair_signs, k_mask, mask_p, mask_q, leaf_id
        The mask parameters the encode used; needed only for dropout
        recovery.
    use_pallas : bool, optional
        Force the fused Pallas scatter kernel (TPU default) or the XLA
        scatter fallback; ``None`` picks by backend.

    Returns
    -------
    f32[size]
        The dense aggregate of the surviving clients' weighted sparse
        updates, masks cancelled. The caller normalizes by the survivors'
        total weight (core/fedavg.py).
    """
    extra = None
    if alive is not None and pair_seeds is not None and k_mask > 0:
        extra = dropout_cancel_streams_seeded(
            pair_seeds, pair_signs, alive, nb, k_mask, m,
            p=mask_p, q=mask_q, leaf_id=leaf_id)
    elif alive is not None and pair_keys is not None and k_mask > 0:
        extra = dropout_cancel_streams(
            pair_keys, pair_signs, alive, nb, k_mask, m,
            p=mask_p, q=mask_q, leaf_id=leaf_id)
    dense = decode_sum_blocks(
        streams, nb, m, alive=alive, weights=weights, extra=extra,
        use_pallas=use_pallas)
    return dense[:size]


@functools.partial(
    jax.jit,
    static_argnames=("nb", "m", "size", "splits", "k_mask", "mask_p",
                     "mask_q", "use_pallas"))
def decode_leaf_tree(
    streams: StreamBatch,
    *,
    nb: int,
    m: int,
    size: int,
    splits: tuple,
    alive: jax.Array | None = None,
    weights: jax.Array | None = None,
    pair_signs: jax.Array | None = None,
    pair_seeds: jax.Array | None = None,
    k_mask: int = 0,
    mask_p: float = -1.0,
    mask_q: float = 2.0,
    leaf_id: int | jax.Array = 0,
    use_pallas: bool | None = None,
) -> jax.Array:
    """Hierarchical twin of :func:`decode_leaf_batch`: identical arguments
    plus the static ``splits`` boundary tuple (see :func:`tree_splits`), and
    identical — bit-exact — output. Dropout recovery streams join the round
    stream before range routing, so each sub-aggregator cancels the
    reconstruction masks landing in its own index range (DESIGN.md §13)."""
    extra = None
    if alive is not None and pair_seeds is not None and k_mask > 0:
        extra = dropout_cancel_streams_seeded(
            pair_seeds, pair_signs, alive, nb, k_mask, m,
            p=mask_p, q=mask_q, leaf_id=leaf_id)
    dense = decode_sum_tree(
        streams, nb, m, splits=splits, alive=alive, weights=weights,
        extra=extra, use_pallas=use_pallas)
    return dense[:size]


# ----------------------------------------------------- the stream exchange
def all_gather_round(tree, axis_name: str, *, tiled: bool = False,
                     replicate: bool = False):
    """all_gather every array of one round's wire payload over the
    federation/clients axis — the ONE collective of the sparse exchange
    (DESIGN.md §11/§12). Every stream consumer (the sharded round below, both
    launch/train.py step builders) routes its gather through here, so a new
    wire payload (e.g. packed codec words) lands in one place.

    ``replicate`` first pins each leaf replicated *within* the participant
    ("gather to leader, then exchange"): XLA's partial-manual partitioner
    cannot form cross-participant peer groups for tensors still sharded over
    the auto axes (hard CHECK) — the launcher's FL mesh needs this, the
    full-manual clients mesh does not.
    """
    def g(x):
        if replicate:
            x = jax.lax.with_sharding_constraint(
                x, jax.sharding.PartitionSpec())
        return jax.lax.all_gather(x, axis_name, axis=0, tiled=tiled)

    return jax.tree_util.tree_map(g, tree)


def gather_streams(stream, axis_name: str, *, tiled: bool = False,
                   replicate: bool = False) -> StreamBatch:
    """Gather one participant's stream into the round's stacked
    ``StreamBatch`` (accepts anything with ``.indices``/``.values``)."""
    idx, vals = all_gather_round((stream.indices, stream.values), axis_name,
                                 tiled=tiled, replicate=replicate)
    return StreamBatch(indices=idx, values=vals)


# ----------------------------------------- client-parallel (sharded) round
def shard_map_clients(f, mesh, in_specs, out_specs):
    """Full-manual shard_map across jax versions (1-D ``clients`` mesh).

    jax >= 0.6 exposes jax.shard_map(check_vma=); earlier versions have
    jax.experimental.shard_map.shard_map(check_rep=). The partial-manual
    variant (manual over one axis of a larger mesh) lives in launch/train.py;
    this one is full manual, which every jaxlib >= 0.4.36 partitions fine.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def shard_client_tree(tree, mesh):
    """Place every leaf of a client-stacked pytree (leading axis = clients)
    with its leading axis partitioned over the ``clients`` mesh — so the
    shard_map programs consume it without a gather-then-scatter reshard."""
    from jax.sharding import NamedSharding, PartitionSpec

    def put(x):
        spec = PartitionSpec(CLIENT_AXIS, *((None,) * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, tree)


def can_shard_clients(mesh, n_clients: int) -> bool:
    """True iff ``mesh`` can host a client-parallel round for this cohort:
    a >1-device 1-D ``clients`` mesh whose size divides the cohort evenly
    (shard_map needs equal shards). Callers fall back to the vmap path
    otherwise."""
    if mesh is None:
        return False
    if tuple(mesh.axis_names) != (CLIENT_AXIS,):
        return False
    n_dev = mesh.devices.size
    return n_dev > 1 and n_clients % n_dev == 0


@functools.lru_cache(maxsize=None)
def _sharded_leaf_program(mesh, k: int, nb: int, m: int, size: int,
                          selector: str, sample_frac: float, k_mask: int,
                          mask_p: float, mask_q: float, with_dropout: bool,
                          use_pallas, codec: str = "f32",
                          splits: tuple = (), dp_sigma: float = 0.0):
    """Build + cache the jitted shard_map program for one leaf signature.

    The cache key is the static signature (mesh + block layout + schedule
    ``k`` + mask config); jit itself re-specializes on shapes/dtypes. One
    program per (leaf shape, k, k_mask) — the same re-specialization budget
    as the serial ``encode_leaf_batch``/``decode_leaf_batch`` pair.
    """
    P = jax.sharding.PartitionSpec
    with_masks = k_mask > 0

    def body(updates_l, residuals_l, weights_l, pair_seeds, pair_signs,
             recovery_seeds, alive, dp_seeds, dp_support_seed, leaf_id):
        c_loc = updates_l.shape[0]
        leaf_shape = updates_l.shape[1:]
        acc = jax.vmap(lambda u, r: to_blocks(
            r.astype(jnp.float32) + u.astype(jnp.float32), nb, m))(
                updates_l, residuals_l)
        i0 = jax.lax.axis_index(CLIENT_AXIS) * c_loc
        dp_support = None
        if dp_sigma > 0.0:
            from repro.core import dp as dp_mod

            # every device derives the IDENTICAL public support from the
            # replicated (round, leaf) seed — common across the whole cohort,
            # bit-identical with the serial encode by construction
            dp_support = dp_mod.common_support(
                dp_support_seed, nb, min(int(k), m), m, leaf_id)
        if with_masks:
            seeds_rows = jax.lax.dynamic_slice_in_dim(
                pair_seeds, i0, c_loc, 0)
            signs_rows = jax.lax.dynamic_slice_in_dim(
                jnp.asarray(pair_signs, jnp.float32), i0, c_loc, 0)
            m_idx, m_vals = mask_streams_rows(
                seeds_rows, signs_rows, nb, k_mask, m,
                p=mask_p, q=mask_q, leaf_id=leaf_id)

            def one(acc_c, mi, mv, srow, w_c):
                return encode_client_blocks(
                    acc_c, k, selector=selector, sample_frac=sample_frac,
                    mask_idx=mi, mask_vals=mv, pair_signs_row=srow,
                    k_mask=k_mask, mask_p=mask_p, mask_q=mask_q, weight=w_c,
                    dp_support=dp_support)

            gidx, vals, new_acc = jax.vmap(one)(
                acc, m_idx, m_vals, signs_rows, weights_l)
        else:
            def one_plain(acc_c, w_c):
                return encode_client_blocks(
                    acc_c, k, selector=selector, sample_frac=sample_frac,
                    weight=w_c, dp_support=dp_support)

            gidx, vals, new_acc = jax.vmap(one_plain)(acc, weights_l)
        if dp_sigma > 0.0:
            # each device noises its OWN clients' rows from the same seed
            # vector the serial round folds — bit-identical by construction
            dp_rows = jax.lax.dynamic_slice_in_dim(dp_seeds, i0, c_loc, 0)
            vals = dp_mod.add_stream_noise(
                vals, dp_rows, sigma=dp_sigma, leaf_id=leaf_id,
                k_data=min(int(k), m))
        # the server reduction: ONE collective over the clients axis. An
        # all_gather of the sparse streams (then the identical full fused
        # scatter-add on every device) rather than a psum of per-device dense
        # partials — the gather moves C*k_total stream slots instead of the
        # nb*m dense buffer, and, because every device then runs the very same
        # scatter over the very same flat stream, the sharded round is
        # bit-exact with the serial decode (a psum's tree-order partial sums
        # are not). With a quantized codec the gathered payload is the packed
        # wire itself — delta-packed index words + value words + row scales —
        # and every device unpacks/dequantizes the identical words, so the
        # codec round stays bit-exact with the serial codec round too (the
        # per-row quantize is shard-local and identical on both paths).
        if codec != "f32":
            from repro.core import codecs

            cols, q, scales, new_acc = codec_wire_stage(
                gidx, vals, new_acc, weights_l, m, codec)
            iw, vw = codecs.pack_stream_rows(cols, q, m=m, codec=codec)
            g_iw, g_vw, g_sc = all_gather_round(
                (iw, vw, scales), CLIENT_AXIS, tiled=True)
            cols_g, q_g = codecs.unpack_stream_rows(
                g_iw, g_vw, k=q.shape[-1], m=m, codec=codec)
            rows_b = jnp.arange(nb, dtype=jnp.int32)[None, :, None]
            g_idx = (rows_b * m + cols_g).astype(jnp.int32)
            g_val = codecs.dequantize_rows(q_g, g_sc)
        else:
            g_idx, g_val = all_gather_round((gidx, vals), CLIENT_AXIS,
                                            tiled=True)
        extra = None
        if with_dropout and with_masks:
            extra = dropout_cancel_streams_seeded(
                recovery_seeds, pair_signs, alive, nb, k_mask, m,
                p=mask_p, q=mask_q, leaf_id=leaf_id)
        gathered = StreamBatch(indices=g_idx, values=g_val)
        if splits:
            # hierarchical decode over the gathered stream: replicated on
            # fake CPU devices (like the flat scatter above), range-sharded
            # on real hierarchies — bit-exact either way (§13)
            dense = decode_sum_tree(
                gathered, nb, m, splits=splits,
                alive=alive if with_dropout else None, extra=extra,
                use_pallas=use_pallas)
        else:
            dense = decode_sum_blocks(
                gathered, nb, m,
                alive=alive if with_dropout else None, extra=extra,
                use_pallas=use_pallas)  # with_dropout: survivor gate
        new_res = jax.vmap(lambda b: from_blocks(b, size, leaf_shape))(
            new_acc).astype(residuals_l.dtype)
        return dense[:size], new_res

    fn = shard_map_clients(
        body, mesh,
        in_specs=(P(CLIENT_AXIS), P(CLIENT_AXIS), P(CLIENT_AXIS),
                  P(), P(), P(), P(), P(), P(), P()),
        out_specs=(P(), P(CLIENT_AXIS)))
    return jax.jit(fn)


def encode_decode_leaf_sharded(
    mesh,
    updates: jax.Array,        # [C, *leaf_shape] stacked client updates
    residuals: jax.Array,      # [C, *leaf_shape] stacked error feedback
    *,
    k: int,
    nb: int,
    m: int,
    size: int,
    selector: str = "exact",
    sample_frac: float = 0.01,
    pair_seeds: jax.Array | None = None,
    pair_signs: jax.Array | None = None,
    recovery_seeds: jax.Array | None = None,
    alive: jax.Array | None = None,
    k_mask: int = 0,
    mask_p: float = -1.0,
    mask_q: float = 2.0,
    leaf_id: int | jax.Array = 0,
    weights: jax.Array | None = None,
    use_pallas: bool | None = None,
    codec: str = "f32",
    topology: str = "flat",
    tree_groups: int = 0,
    dp_sigma: float = 0.0,
    dp_seeds: jax.Array | None = None,
    dp_support_seed: jax.Array | int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Client-parallel encode + decode for one leaf, fused in one shard_map.

    The device-sharded twin of the ``encode_leaf_batch`` -> ``decode_leaf_batch``
    pair: clients are partitioned over the 1-D ``clients`` mesh, each device
    runs the THGS encode and pair-mask PRNG for its shard, and the server
    reduction is a single all_gather of the sparse streams followed by the
    same fused scatter-add on every device (bit-exact with the serial path —
    see the in-body comment for why not a dense psum). Dropout recovery
    (``alive`` + ``recovery_seeds``) replicates the reconstruction streams,
    exactly as the serial decode does.

    Requires ``can_shard_clients(mesh, C)``; returns
    ``(dense f32[size] replicated, new_residuals [C, *leaf_shape]
    client-sharded)``. The caller normalizes by the survivors' total weight,
    as with the serial pair.
    """
    C = updates.shape[0]
    assert can_shard_clients(mesh, C), (
        f"mesh {mesh} cannot shard {C} clients; use encode_leaf_batch")
    with_masks = pair_seeds is not None and k_mask > 0 and C >= 2
    reject_codec_with_masks(codec, k_mask if with_masks else 0)
    if dp_sigma > 0.0:
        from repro.core import dp as dp_mod

        dp_mod.reject_codec_with_noise(codec, dp_sigma)
        if dp_seeds is None:
            raise ValueError("dp_sigma > 0 requires dp_seeds")
    if dp_seeds is None:
        # placeholder operand keeps the program arity fixed; the dp_sigma
        # branch is baked statically so it is never read
        dp_seeds = jnp.zeros((C,), jnp.uint32)
    # dropouts gate the decode even without masks (serial parity: the serial
    # path passes `alive` to decode_leaf_batch whenever clients dropped);
    # recovery streams additionally need the masks
    with_dropout = alive is not None
    if weights is None:
        weights = jnp.ones((C,), jnp.float32)
    if not with_masks:
        k_mask = 0
        # placeholder operands keep the program arity fixed; the with_masks
        # branch is baked statically so they are never read
        pair_seeds = jnp.zeros((C, C), jnp.uint32)
        pair_signs = jnp.zeros((C, C), jnp.float32)
    if recovery_seeds is None:
        recovery_seeds = pair_seeds
    if alive is None:
        alive = jnp.ones((C,), bool)
    if topology not in ("flat", "tree"):
        raise ValueError(f"unknown topology {topology!r}")
    splits = ()
    if topology == "tree":
        splits = tree_splits(nb * m, tree_groups if tree_groups > 0
                             else max(2, int(round(C ** 0.5))))
    fn = _sharded_leaf_program(
        mesh, int(k), int(nb), int(m), int(size), selector,
        float(sample_frac), int(k_mask), float(mask_p), float(mask_q),
        bool(with_dropout), use_pallas, str(codec), splits, float(dp_sigma))
    return fn(updates, residuals, jnp.asarray(weights, jnp.float32),
              pair_seeds, pair_signs, recovery_seeds, alive,
              jnp.asarray(dp_seeds, jnp.uint32),
              jnp.asarray(dp_support_seed, jnp.uint32),
              jnp.asarray(leaf_id))
