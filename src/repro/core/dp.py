"""Distributed differential privacy under secure aggregation (DESIGN.md §15).

The DP plane composes with the sparse secagg data plane without touching the
wire format. Per round, each client:

1. **clips** the encoder's actual input — its error-feedback accumulator
   ``residual + delta`` — to a global L2 bound ``S`` (``DPConfig.clip``), so
   the bound covers the *full stream the client emits*, error feedback
   included (the residual carries clipped-but-untransmitted mass forward;
   it re-enters next round's accumulator and is clipped again);
2. releases gradient values ONLY on the round's **common public support**
   (``kernels/ref.dp_support_stream_ref``) — ``k`` positions per block drawn
   from (dp seed, round, leaf), identical for every client and independent
   of the data, so the transmitted indices leak nothing (a data-dependent
   top-k support would be unaccounted leakage, and would leave coordinates
   in only one client's support carrying a single client's noise). Pair-mask
   slots carry *masks only* — no gradient values ride them under DP;
3. adds grid-rounded Gaussian noise to each released slot *under* its pair
   masks. Noise is drawn on the same f32-exact 2^-24 grid as the masks
   (``kernels/ref.dp_noise_stream_ref``), so masks cancel and noise survives
   exactly in the server's scatter-add — the server only ever sees the
   noised sum, and the noise adds ZERO wire bits.

Per-client noise is ``sigma_client = z * S / sqrt(C)`` with noise multiplier
``z = DPConfig.sigma`` over a ``C``-client cohort. Because every survivor
releases (and noises) the very same support, EVERY released coordinate of
the sum carries all ``d`` survivors' noise: stddev ``z * S * sqrt(d / C)``
against per-client sensitivity ``S`` — the distributed-DP analogue of the
central Gaussian mechanism (Byrd & Polychroniadou 2020; Beguier et al. 2020
for the grid/quantized composition), valid against an honest-but-curious
server that observes only the masked sum. The accountant composes over the
survivor-aware multiplier ``z_eff = z * sqrt(d / C)`` per round
(``CommLedger.privacy``); uniform client weights are required and enforced
(a weighted stream would scale a contribution past ``S``).

Replayability: noise seeds are derived host-side per (dp seed, round, client)
and the support seed per (dp seed, round) via sha256 (:meth:`DPConfig
.client_seeds` / :meth:`DPConfig.support_seed` — the derivation discipline of
``masks.pair_seed``) and folded with the leaf id in-trace, so a resumed sim
replays the identical noise and support streams from config + round index
alone, and the client-sharded round slices the same seed rows the serial
round uses (bit-identical by construction).

``sigma == 0`` and ``clip == inf`` statically skip every DP op, making such
rounds bit-identical to plain secagg rounds (property-tested in
tests/test_dp.py, same style as the tau=0 async and tree==flat guarantees).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import PyTree


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Distributed-DP knobs for one federated run.

    ``clip`` is the per-client global-L2 sensitivity bound S applied to the
    local model delta (inf disables clipping); ``sigma`` is the *noise
    multiplier* z of the cohort sum — each client adds ``z * S / sqrt(C)``.
    ``delta`` is the accountant's target δ. Defaults are the identity
    (``clip=inf, sigma=0``): a DPConfig() round is bit-identical to no DP.
    """

    clip: float = math.inf
    sigma: float = 0.0
    delta: float = 1e-5
    seed: int = 0xD1FFC0DE

    @property
    def clips(self) -> bool:
        return math.isfinite(self.clip)

    @property
    def noised(self) -> bool:
        return self.sigma > 0.0

    @property
    def active(self) -> bool:
        return self.clips or self.noised

    def validate(self) -> None:
        if not (self.clip > 0.0):
            raise ValueError(f"dp.clip must be positive, got {self.clip}")
        if self.sigma < 0.0:
            raise ValueError(f"dp.sigma must be >= 0, got {self.sigma}")
        if self.noised and not self.clips:
            raise ValueError(
                "dp.sigma > 0 requires a finite dp.clip: the noise scale is "
                "sigma * clip / sqrt(C), and unclipped updates have no "
                "sensitivity bound to calibrate against")
        if not (0.0 < self.delta < 1.0):
            raise ValueError(f"dp.delta must be in (0, 1), got {self.delta}")

    def sigma_client(self, cohort: int) -> float:
        """Per-client noise stddev so the full-cohort sum carries z*S."""
        if not self.noised:
            return 0.0
        return self.sigma * self.clip / math.sqrt(max(1, cohort))

    def client_seeds(self, round_t: int, client_ids: Sequence[int]):
        """uint32[C] noise-stream seeds for one round's participants.

        sha256 of (dp seed, round, client) — the derivation discipline of
        ``masks.pair_seed``, so the stream is a pure function of config +
        round + client id: resume replays it bit-identically and the
        sharded round slices the identical rows.
        """
        out = np.empty(len(client_ids), np.uint32)
        for i, c in enumerate(client_ids):
            h = hashlib.sha256(
                f"dpnoise:{self.seed}:{round_t}:{int(c)}".encode()).digest()
            out[i] = int.from_bytes(h[:4], "little")
        return out

    def support_seed(self, round_t: int) -> np.uint32:
        """uint32 seed of one round's PUBLIC common release support.

        A pure function of (dp seed, round) — shared by the whole cohort and
        independent of any client's data, so the support indices the stream
        transmits under DP noise release nothing
        (``kernels/ref.dp_support_stream_ref`` folds the leaf id in-trace).
        """
        h = hashlib.sha256(
            f"dpsupport:{self.seed}:{round_t}".encode()).digest()
        # np.uint32, not int: the seed crosses jit boundaries as a traced
        # scalar, and a Python int above 2^31 overflows the weak-int32 parse
        return np.uint32(int.from_bytes(h[:4], "little"))


# ------------------------------------------------------------------ clipping
@functools.partial(jax.jit, static_argnames=("clip",))
def clip_client_updates(updates: PyTree, *, clip: float) -> PyTree:
    """Per-client global-L2 clip of stacked client trees (leading axis C).

    ``factor = min(1, clip / norm)`` over each client's full tree, norm and
    scaling computed in f32 (the engine's working precision — DESIGN.md §15).
    Clients already inside the bound get factor exactly 1.0, and ``x * 1.0``
    is a bitwise no-op in f32 — so clipping never perturbs compliant clients.

    Under DP the server (fedavg.run_round) clips the error-feedback
    accumulator ``residual + delta`` — the encoder's actual input — not the
    fresh delta alone: error feedback accumulates untransmitted mass across
    rounds, so only clipping what the encoder consumes bounds the L2 norm of
    the stream a client actually emits by ``clip``.
    """
    leaves = jax.tree_util.tree_leaves(updates)
    sq = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)).reshape(x.shape[0], -1),
                axis=1)
        for x in leaves)
    norm = jnp.sqrt(sq)
    factor = jnp.minimum(1.0, jnp.float32(clip) / jnp.maximum(norm, 1e-30))

    def scale(x):
        f = factor.reshape((-1,) + (1,) * (x.ndim - 1))
        return (x.astype(jnp.float32) * f).astype(x.dtype)

    return jax.tree_util.tree_map(scale, updates)


# ------------------------------------------------------------ noise injection
def add_stream_noise(
    values: jax.Array,          # f32[..., nb, k_total] batched stream values
    dp_seeds: jax.Array,        # uint32[...] per-client noise seeds
    *,
    sigma: float,               # per-client noise stddev (sigma_client)
    leaf_id,
    k_data: int,                # released (common-support) slots per block
) -> jax.Array:
    """Inject grid-exact Gaussian noise into a batched stream's values.

    One noise draw per *released* slot — the leading ``k_data`` common-
    support slots of each block, the only slots that carry gradient values
    under DP (module docstring). Mask slots carry masks only and stay
    noise-free: their contributions cancel pairwise in the aggregate, so
    noise there would add error without adding privacy. The noise is added
    under the pair masks (to the same f32 values the masks were added to),
    drawn from the per-(round, client) counter stream folded with the leaf
    id — exactly the pair-mask stream discipline, so serial/sharded/resumed
    rounds agree bit for bit.
    """
    from repro.kernels import ref as kref

    seeds = kref.fold_leaf_seed(jnp.asarray(dp_seeds, jnp.uint32), leaf_id)
    noise = kref.dp_noise_stream_ref(
        seeds, values.shape[-2], int(k_data), sigma=float(sigma))
    pad = values.shape[-1] - int(k_data)
    if pad:
        noise = jnp.concatenate(
            [noise, jnp.zeros(noise.shape[:-1] + (pad,), noise.dtype)], -1)
    return values + noise


def common_support(support_seed, nb: int, k: int, m: int,
                   leaf_id) -> jax.Array:
    """int32[nb, k] PUBLIC common release support for one (round, leaf).

    Folds the leaf id into the round's support seed in-trace (the pair-seed
    discipline) and draws the shared indices every client of the round
    releases on (``kernels/ref.dp_support_stream_ref``).
    """
    from repro.kernels import ref as kref

    seed = kref.fold_leaf_seed(jnp.asarray(support_seed, jnp.uint32), leaf_id)
    return kref.dp_support_stream_ref(seed, nb, k, m)


def reject_codec_with_noise(codec: str, sigma: float) -> None:
    """DP noise, like pair masks, cancels/composes only on the f32 grid —
    quantized wire codecs would re-grid the noised values. One shared
    rejection (the RPL003 discipline, mirroring reject_codec_with_masks)."""
    if sigma > 0.0 and codec != "f32":
        raise ValueError(
            f"codec {codec!r} cannot carry DP noise: grid-exact noise "
            "composition requires the f32 wire (codec='f32')")


# ------------------------------------------------------- privacy accounting
# Renyi orders for the RDP accountant; the standard grid spans small orders
# (tight for large noise) through large ones (tight for small noise).
RDP_ALPHAS: tuple[float, ...] = (
    1.25, 1.5, 1.75, 2.0, 2.5, 3.0, 3.5, 4.0, 5.0, 6.0, 8.0, 10.0, 12.0,
    16.0, 20.0, 24.0, 32.0, 48.0, 64.0, 96.0, 128.0, 256.0, 512.0)


def gaussian_rdp(noise_multiplier: float, alpha: float) -> float:
    """RDP of the Gaussian mechanism at order alpha: alpha / (2 z^2)."""
    if noise_multiplier <= 0.0:
        return math.inf
    return alpha / (2.0 * noise_multiplier ** 2)


def compose_epsilon(noise_multipliers: Sequence[float], delta: float) -> float:
    """(ε at δ) of adaptively composed Gaussian mechanisms.

    Additive RDP composition across rounds at each order, then the standard
    RDP→(ε, δ) conversion ``ε = min_α [ Σ_t α/(2 z_t²) + log(1/δ)/(α−1) ]``
    (Mironov 2017). Any round with ``z <= 0`` (no noise) makes the
    composition non-private: returns inf. An empty sequence returns 0.
    """
    zs = [float(z) for z in noise_multipliers]
    if not zs:
        return 0.0
    if any(z <= 0.0 for z in zs):
        return math.inf
    if not (0.0 < delta < 1.0):
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    inv_2z2 = sum(1.0 / (2.0 * z * z) for z in zs)
    return min(alpha * inv_2z2 + math.log(1.0 / delta) / (alpha - 1.0)
               for alpha in RDP_ALPHAS)


def round_epsilon(noise_multiplier: float, delta: float) -> float:
    """Single-round (ε at δ) of one Gaussian mechanism."""
    return compose_epsilon([noise_multiplier], delta)
