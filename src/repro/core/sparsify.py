"""THGS sparsification primitives (paper Alg. 1).

Per leaf (== per layer, "hierarchical"): accumulate the incoming gradient into the
error-feedback residual, select the top-k of the accumulated magnitude, emit the
selected (indices, values) and keep the remainder as the new residual.

Selection strategies:
  * 'exact'   — jax.lax.top_k over the flat leaf (small/medium tensors).
  * 'sampled' — threshold estimated from a strided subsample's top-k; membership by
                magnitude >= threshold, compacted to a static k via top_k on the
                masked magnitudes (ties at the threshold resolved arbitrarily).
                Sub-linear selection cost; used for very large leaves.
  * 'local'   — the caller splits the leaf across shards and runs 'exact' per shard
                with k/num_shards each (the launcher does this inside shard_map).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import SparseStream, THGSConfig


class LeafSparsification(NamedTuple):
    stream: SparseStream   # top-k indices/values of the accumulated gradient
    residual: jax.Array    # same shape as the leaf; acc with top-k zeroed
    threshold: jax.Array   # scalar delta actually used


def _exact_topk(flat_abs: jax.Array, k: int):
    vals, idx = jax.lax.top_k(flat_abs, k)
    return vals, idx


def _sampled_topk(flat_abs: jax.Array, k: int, sample_frac: float):
    """Estimate the k-th magnitude from a strided subsample, then compact.

    The estimate is conservative (threshold from the sample's matching quantile);
    we still return exactly k entries by top_k over the thresholded magnitudes,
    which equals exact top-k whenever the estimate is below the true k-th value
    and degrades gracefully (ties near delta) otherwise.
    """
    n = flat_abs.shape[0]
    m = max(int(n * sample_frac), min(n, 1024))
    stride = max(n // m, 1)
    sample = flat_abs[::stride]
    ks = max(1, min(sample.shape[0], int(k * sample.shape[0] / n)))
    thresh = jax.lax.top_k(sample, ks)[0][-1]
    gated = jnp.where(flat_abs >= thresh, flat_abs, 0.0)
    return jax.lax.top_k(gated, k)


def sparsify_leaf(
    grad: jax.Array,
    residual: jax.Array,
    k: int,
    cfg: THGSConfig,
) -> LeafSparsification:
    """One THGS layer step: error-feedback accumulate -> top-k -> residual."""
    acc = (residual + grad).astype(grad.dtype)
    flat = acc.reshape(-1)
    k = int(min(k, flat.shape[0]))
    abs_flat = jnp.abs(flat)
    if cfg.selector == "sampled":
        top_vals_abs, idx = _sampled_topk(abs_flat, k, cfg.sample_frac)
    else:  # 'exact' and 'local' (the launcher pre-shards for 'local')
        top_vals_abs, idx = _exact_topk(abs_flat, k)
    delta = top_vals_abs[-1]
    values = flat[idx]
    new_resid_flat = flat.at[idx].set(0.0)
    return LeafSparsification(
        stream=SparseStream(indices=idx.astype(jnp.int32), values=values),
        residual=new_resid_flat.reshape(acc.shape),
        threshold=delta,
    )


def densify(stream: SparseStream, size: int, dtype=jnp.float32) -> jax.Array:
    """Scatter a stream back to a dense flat vector (server-side decode)."""
    return jnp.zeros((size,), dtype).at[stream.indices].add(
        stream.values.astype(dtype)
    )


def first_occurrence_mask(indices: jax.Array) -> jax.Array:
    """Boolean per slot: True iff this slot is the first occurrence of its index.

    Sort-based (O(k log k)): duplicates of an index occupy consecutive ranks after
    sorting; a slot is a first occurrence iff its sorted predecessor differs.
    """
    order = jnp.argsort(indices)
    sorted_idx = indices[order]
    is_first_sorted = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_idx[1:] != sorted_idx[:-1]]
    )
    # scatter back to original slot order
    out = jnp.zeros(indices.shape, bool).at[order].set(is_first_sorted)
    return out


def member_of(query: jax.Array, table: jax.Array) -> jax.Array:
    """Boolean per query slot: does the index appear anywhere in `table`?

    Sorted-table binary search (O(q log t)); both arrays are int32 flat indices.
    """
    st = jnp.sort(table)
    pos = jnp.searchsorted(st, query)
    pos = jnp.clip(pos, 0, st.shape[0] - 1)
    return st[pos] == query
