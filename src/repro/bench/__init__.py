"""repro.bench — the machine-readable performance trajectory.

``python -m repro.bench`` times the hot paths (the client-parallel federated
round, serial vs device-sharded, the aggregation kernels, the flat-vs-tree
cohort scaling sweep, and the hot-swap serving path) and emits schema'd JSON
documents — ``BENCH_round.json`` / ``BENCH_agg.json`` / ``BENCH_cohort.json``
/ ``BENCH_serve.json`` at the repo root — that CI gates every PR against
(``--gate``). EXPERIMENTS.md documents the schema and how to refresh the
committed baselines.

This package also subsumes ``benchmarks/run.py``'s CSV printer: the legacy
paper-table suites (table1/table2/fig1/fig3/roofline) remain importable from
the repo-root ``benchmarks`` package and run here via ``--csv --only ...``;
``benchmarks/run.py`` is a deprecation shim over that entry point.

Import discipline: this module and ``repro.bench.schema`` import no jax —
the CLI must be able to set ``XLA_FLAGS`` (device count) before the first
jax import, and the CI gate runs without touching a backend at all. The
suite implementations (``round_bench``, ``agg_bench``, ``cohort_bench``)
are imported lazily.
"""
from __future__ import annotations

from repro.bench.schema import (SCHEMA_VERSION, gate_compare, iter_entries,
                                make_doc, validate_doc)

# JSON suites: name -> (module under repro.bench, default output filename)
JSON_SUITES = {
    "round": ("repro.bench.round_bench", "BENCH_round.json"),
    "agg": ("repro.bench.agg_bench", "BENCH_agg.json"),
    "cohort": ("repro.bench.cohort_bench", "BENCH_cohort.json"),
    "serve": ("repro.bench.serve_bench", "BENCH_serve.json"),
}

# legacy CSV-only suites living in the repo-root benchmarks/ package
LEGACY_SUITES = {
    "table1": ("benchmarks.table1_model_sizes", "run"),
    "table2": ("benchmarks.table2_comm_cost", "run"),
    "fig1": ("benchmarks.fig1_sparsity_accuracy", "run"),
    "fig3": ("benchmarks.fig3_thgs_vs_flat", "run"),
    "roofline": ("benchmarks.roofline", "run"),
}


def run_suite(name: str, quick: bool = False) -> list[dict]:
    """Run one suite by name; returns normalized entry dicts."""
    import importlib

    if name in JSON_SUITES:
        mod = importlib.import_module(JSON_SUITES[name][0])
        return mod.entries(quick=quick)
    if name in LEGACY_SUITES:
        mod_name, fn_name = LEGACY_SUITES[name]
        try:
            mod = importlib.import_module(mod_name)
        except ImportError as e:
            raise ImportError(
                f"legacy suite {name!r} needs the repo-root 'benchmarks' "
                "package on sys.path (run from the repository root)") from e
        rows = getattr(mod, fn_name)(quick=quick)
        return [{"name": n, "us_per_call": float(us), "derived": str(d)}
                for n, us, d in rows]
    raise KeyError(
        f"unknown suite {name!r}; know {sorted(JSON_SUITES)} + "
        f"{sorted(LEGACY_SUITES)}")


__all__ = [
    "JSON_SUITES", "LEGACY_SUITES", "SCHEMA_VERSION", "gate_compare",
    "iter_entries", "make_doc", "run_suite", "validate_doc",
]
