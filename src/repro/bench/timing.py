"""Shared timing helpers for the bench suites."""
from __future__ import annotations

import time
from typing import Callable


def time_us(fn: Callable[[], object], reps: int, *, warmup: int = 1) -> float:
    """Mean wall-clock microseconds per call after ``warmup`` compile calls.

    The callable must block on its own result (``.block_until_ready()``) —
    async dispatch otherwise times the enqueue, not the work.
    """
    for _ in range(max(warmup, 0)):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def entry(name: str, us: float, derived: str = "", *, reps: int = 0) -> dict:
    """One normalized BENCH entry (us == 0.0 marks an info-only row)."""
    e = {"name": name, "us_per_call": float(us), "derived": str(derived)}
    if reps:
        e["reps"] = int(reps)
    return e
