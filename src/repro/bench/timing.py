"""Shared timing helpers for the bench suites."""
from __future__ import annotations

import time
from typing import Callable


def time_us(fn: Callable[[], object], reps: int, *, warmup: int = 1) -> float:
    """Mean wall-clock microseconds per call after ``warmup`` compile calls.

    The callable must block on its own result (``.block_until_ready()``) —
    async dispatch otherwise times the enqueue, not the work.
    """
    for _ in range(max(warmup, 0)):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def measure(fn: Callable[[], object], reps: int, *, warmup: int = 1) -> float:
    """Min-of-reps wall-clock microseconds per call — the canonical suite
    timer.

    Every BENCH suite times through this (tests/test_bench.py pins it): the
    CI gate compares against committed baselines with a 3x slowdown bound,
    and a mean over 2-3 reps of a sub-millisecond op trips it on a single OS
    scheduler stall (PR 6 hit this on the agg micro-entries). The *min* of
    ``max(3, reps)`` single-rep timings is what the op actually costs; the
    ``warmup`` calls absorb compilation.
    """
    for _ in range(max(warmup, 0)):
        fn()
    return min(time_us(fn, 1, warmup=0) for _ in range(max(3, reps)))


def entry(name: str, us: float, derived: str = "", *, reps: int = 0) -> dict:
    """One normalized BENCH entry (us == 0.0 marks an info-only row)."""
    e = {"name": name, "us_per_call": float(us), "derived": str(derived)}
    if reps:
        e["reps"] = int(reps)
    return e
