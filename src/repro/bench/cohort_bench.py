"""Cohort-scaling benchmarks (suite key ``cohort`` -> BENCH_cohort.json).

Clients/s of the server aggregation data plane vs cohort size, flat vs tree
(DESIGN.md §13): for each simulated cohort C in {64, 256, 1024} the suite
synthesizes one round's sparse streams directly (random in-range indices +
normal values — this isolates the decode, no SGD and no mask PRNG in the
timed region) and times

  * ``flat`` — the single fused scatter-add (``streams.decode_sum_blocks``);
  * ``tree`` — G = ~sqrt(C) sub-aggregators each scatter-adding their
    contiguous index range, combined by concatenation
    (``streams.decode_sum_tree``) — bit-exact with flat, so the delta is
    pure execution cost.

An info entry per cohort reports the collective-volume story: the flat
all-gather moves C·k stream slots to every device, the tree's inter-group
combine moves G dense partials totalling one model (O(m)).

Quick and full mode run the SAME cohort sizes — the acceptance trajectory is
the 64/256/1024 sweep itself — with quick shrinking the leaf and rep count.
All entries are min-of-reps (``timing.measure``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.timing import entry, measure
from repro.core import streams
from repro.launch.mesh import default_tree_groups

COHORTS = (64, 256, 1024)


def _one_cohort(n_clients: int, size: int, k: int, reps: int) -> list[dict]:
    key = jax.random.key(n_clients)
    idx = jax.random.randint(key, (n_clients, 1, k), 0, size,
                             dtype=jnp.int32)
    vals = jax.random.normal(jax.random.fold_in(key, 1),
                             (n_clients, 1, k), jnp.float32)
    st = streams.StreamBatch(indices=idx, values=vals)
    groups = default_tree_groups(n_clients)
    splits = streams.tree_splits(size, groups)

    def flat():
        return streams.decode_sum_blocks(st, 1, size).block_until_ready()

    def tree():
        return streams.decode_sum_tree(
            st, 1, size, splits=splits).block_until_ready()

    # parity guard: a benchmark of a wrong decode is worse than no benchmark
    assert bool(jnp.all(flat() == tree())), "tree decode diverged from flat"

    us_flat = measure(flat, reps)
    us_tree = measure(tree, reps)
    stream_mb = n_clients * k * 8 / 1e6        # int32 idx + f32 val per slot
    partial_mb = size * 4 / 1e6                # G partials totalling one model
    tag = f"c{n_clients}_n{size}"
    return [
        entry(f"cohort/flat_{tag}", us_flat,
              f"{n_clients / (us_flat / 1e6):.0f}_clients_per_s", reps=reps),
        entry(f"cohort/tree_{tag}_g{groups}", us_tree,
              f"{n_clients / (us_tree / 1e6):.0f}_clients_per_s", reps=reps),
        entry(f"cohort/volume_{tag}", 0.0,
              f"gather{stream_mb:.2f}MB_vs_combine{partial_mb:.2f}MB"),
    ]


def entries(quick: bool = False) -> list[dict]:
    if quick:
        size, reps = 1 << 12, 3
    else:
        size, reps = 1 << 16, 5
    k = max(1, size // 256)
    out = []
    for C in COHORTS:
        out += _one_cohort(C, size, k, reps)
    return out


def rows(quick: bool = False) -> list[tuple]:
    """Legacy ``(name, us_per_call, derived)`` tuples for the CSV printer."""
    return [(e["name"], e["us_per_call"], e["derived"])
            for e in entries(quick=quick)]
