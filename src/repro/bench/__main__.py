"""CLI for the benchmark suites and the CI regression gate.

    python -m repro.bench --quick                 # BENCH_round.json + BENCH_agg.json (cwd)
    python -m repro.bench --quick --out BENCH_ci.json   # one combined document
    python -m repro.bench --gate BENCH_ci.json    # compare vs committed baselines
    python -m repro.bench --csv --only table2,agg # legacy benchmarks/run.py surface

Device forcing: the sharded-round benchmark needs >1 device, so unless
``XLA_FLAGS`` already pins a host device count (or ``--devices 0`` opts out)
the CLI injects ``--xla_force_host_platform_device_count=<N>`` before the
first jax import. The flag only affects the CPU platform — on TPU it is
inert, and the real device topology wins.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def _force_devices(n: int) -> None:
    if "jax" in sys.modules:  # too late to change the platform
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if _DEVICE_FLAG in flags:
        return
    os.environ["XLA_FLAGS"] = f"{flags} {_DEVICE_FLAG}={n}".strip()


def main(argv=None) -> int:
    from repro.bench import schema

    ap = argparse.ArgumentParser(
        prog="python -m repro.bench",
        description="Run the perf suites / gate a run against the baselines.")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized workloads (the committed baselines are "
                         "quick-mode; entry names encode the size)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suites; JSON suites: "
                         "round,agg,cohort,serve; legacy CSV-only: "
                         "table1,table2,fig1,fig3,roofline")
    ap.add_argument("--out", default=None,
                    help="write ONE combined JSON document here instead of "
                         "per-suite BENCH_<suite>.json files in the cwd")
    ap.add_argument("--csv", action="store_true",
                    help="print legacy 'name,us_per_call,derived' CSV rows "
                         "instead of writing JSON")
    ap.add_argument("--devices", type=int, default=8,
                    help="force this many host-platform devices before jax "
                         "init (CPU only; 0 = leave XLA_FLAGS alone)")
    ap.add_argument("--gate", default=None, metavar="CURRENT_JSON",
                    help="gate mode: compare this document against the "
                         "baselines and exit 1 on regression (runs nothing)")
    ap.add_argument("--baseline", action="append", default=None,
                    help="baseline document(s) for --gate (default: "
                         "BENCH_round.json BENCH_agg.json "
                         "BENCH_cohort.json BENCH_serve.json)")
    ap.add_argument("--max-slowdown", type=float,
                    default=schema.DEFAULT_MAX_SLOWDOWN,
                    help="gate threshold (default %(default)s; generous — "
                         "CI runners are noisy)")
    args = ap.parse_args(argv)

    if args.gate is not None:
        current = schema.load_doc(args.gate)
        baselines = []
        for p in (args.baseline or ["BENCH_round.json", "BENCH_agg.json",
                                    "BENCH_cohort.json",
                                    "BENCH_serve.json"]):
            baselines.append(schema.load_doc(p))
        failures, compared = schema.gate_compare(
            current, baselines, max_slowdown=args.max_slowdown)
        if compared == 0:
            print("bench gate: no comparable entries — baseline stale? "
                  "(quick vs full runs never share entry names)",
                  file=sys.stderr)
            return 1
        for line in failures:
            print(f"bench gate REGRESSION: {line}", file=sys.stderr)
        print(f"bench gate: {compared} entries compared, "
              f"{len(failures)} regression(s) at >{args.max_slowdown:.1f}x")
        return 1 if failures else 0

    from repro.bench import JSON_SUITES, LEGACY_SUITES, make_doc, run_suite

    default = list(JSON_SUITES)
    chosen = args.only.split(",") if args.only else default
    if args.csv and args.out:
        print("error: --csv and --out are mutually exclusive (CSV mode "
              "writes no JSON; refresh baselines without --csv)",
              file=sys.stderr)
        return 2
    # every JSON-document run uses the same forced topology so a partial
    # refresh (--only agg) stays comparable with the full one and with CI.
    # CSV mode (the benchmarks/run.py legacy surface, whose default list
    # includes 'agg') keeps the real device count — forcing 8 fake devices
    # there would change the paper-table suites' timings and let the sim
    # engine's shard_clients='auto' silently go multi-device
    if args.devices and not args.csv and any(c in JSON_SUITES
                                             for c in chosen):
        _force_devices(args.devices)
    unknown = [c for c in chosen if c not in {**JSON_SUITES,
                                              **LEGACY_SUITES}]
    if unknown:
        print(f"error: unknown suite(s) {unknown}; know "
              f"{sorted(JSON_SUITES)} + {sorted(LEGACY_SUITES)}",
              file=sys.stderr)
        return 2
    if not args.csv:
        legacy = [c for c in chosen if c in LEGACY_SUITES]
        if legacy:
            print(f"error: {legacy} are CSV-only legacy suites; add --csv "
                  "(benchmarks/run.py does)", file=sys.stderr)
            return 2

    results: dict[str, list[dict]] = {}
    failures = 0
    if args.csv:
        print("name,us_per_call,derived")
    for name in chosen:
        try:
            entries = run_suite(name, quick=args.quick)
        except Exception as e:  # keep the suite going; report the failure
            if args.csv:
                print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
                failures += 1
                continue
            raise
        results[name] = entries
        if args.csv:
            for e in entries:
                print(f"{e['name']},{e['us_per_call']:.1f},{e['derived']}",
                      flush=True)
    if args.csv:
        return 1 if failures else 0

    json_suites = {n: es for n, es in results.items() if n in JSON_SUITES}
    if args.out:
        doc = make_doc(None, suites=json_suites, quick=args.quick)
        tmp = args.out + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
        os.replace(tmp, args.out)
        print(f"wrote {args.out} "
              f"({sum(len(v) for v in json_suites.values())} entries)")
    else:
        for name, entries in json_suites.items():
            path = JSON_SUITES[name][1]
            doc = make_doc(entries, suite=name, quick=args.quick)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(doc, f, indent=1)
            os.replace(tmp, path)
            print(f"wrote {path} ({len(entries)} entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
