"""Serving benchmarks (suite key ``serve`` -> BENCH_serve.json).

The serving-path trajectory of DESIGN.md §16, four timed regions:

* ``serve/infer_*`` — the jitted compile-once classifier batch
  (:class:`~repro.serving.server.ClassifierAdapter` on the Table-1 MLP):
  one fixed-shape ``[max_batch, ...]`` apply, the server's data plane.
* ``serve/swap_pause`` — the double-buffered weight hot swap
  (``hot_swap.WeightBuffers``): staging (restore + device put) happens off
  the serve path, so the pause a request can observe is the pointer flip
  alone. Reported as the min of the swap's own pause stamps; it sits far
  below the gate's 20 µs noise floor by construction.
* ``serve/e2e_p50`` / ``serve/e2e_p99`` — end-to-end request latency
  (submit -> response) through the real server thread + open-loop
  loadgen at a fixed offered QPS, min over reps of each run's
  nearest-rank percentile; a ``serve/sustained_qps`` info row carries
  the achieved throughput of the best rep.
* ``serve/decode_*`` — batched greedy generation
  (:class:`~repro.serving.server.LMAdapter`, donated KV caches): one
  prefill + ``n_new - 1`` decode steps; derived reports tok/s.

All timed entries are min-of-reps (``timing.measure`` or the min over
per-run statistics); the e2e rows assert the zero-dropped-requests
invariant before reporting, so a broken server can't publish a latency.
"""
from __future__ import annotations

import jax
import numpy as np

from repro import configs
from repro.bench.timing import entry, measure
from repro.data import make_lm_tokens
from repro.models import transformer as tf
from repro.models.paper_models import PAPER_MODELS
from repro.serving import (ClassifierAdapter, InferenceServer, LMAdapter,
                           LoadGenerator, ServingMetrics, WeightBuffers)
from repro.serving.metrics import percentile

MODEL = "mnist_mlp"
ARCH = "yi_6b"
MAX_BATCH = 8


def _classifier_entries(reps: int) -> list[dict]:
    model = PAPER_MODELS[MODEL]
    params = model.init(jax.random.key(0))
    adapter = ClassifierAdapter(model, MAX_BATCH)
    rng = np.random.RandomState(0)
    stack = rng.randn(MAX_BATCH, *model.input_shape).astype(np.float32)

    us = measure(lambda: adapter.infer(params, stack), reps)
    rows = [entry(f"serve/infer_{MODEL}_b{MAX_BATCH}", us,
                  f"{MAX_BATCH / (us / 1e6):.0f}_req_per_s", reps=reps)]

    # hot-swap pause: stage off-path, then flip; min of the swap's own stamps
    buffers = WeightBuffers(params, step=0)
    pauses = []
    for _ in range(max(3, reps)):
        buffers.stage(buffers.active_step + 1, params)
        pauses.append(buffers.swap())
    rows.append(entry("serve/swap_pause", min(pauses),
                      "pointer_flip_between_batches", reps=max(3, reps)))
    return rows


def _e2e_rep(model, params, n_req: int, qps: float):
    """One serve run: server thread + open-loop loadgen, no training.
    Returns (p50_us, p99_us, sustained_qps)."""
    metrics = ServingMetrics(offered_qps=qps)
    server = InferenceServer(ClassifierAdapter(model, MAX_BATCH), params,
                             metrics=metrics)
    rng = np.random.RandomState(1)
    payloads = rng.randn(32, *model.input_shape).astype(np.float32)
    gen = LoadGenerator(server, payloads, qps, metrics=metrics)
    server.start()
    try:
        n = gen.run(n_requests=n_req)
        errors = gen.drain()
    finally:
        server.stop()
    assert errors == 0 and metrics.errors == 0, \
        f"e2e bench dropped requests ({errors} drain errors)"
    assert metrics.served == n, "e2e bench served != submitted"
    lats = sorted(metrics.latencies_us)
    # wall_s is stamped by the loadgen (pacing start -> fully drained)
    sustained = metrics.served / max(metrics.wall_s, 1e-9)
    return (percentile(lats, 50), percentile(lats, 99), sustained)


def _e2e_entries(n_req: int, qps: float, reps: int) -> list[dict]:
    model = PAPER_MODELS[MODEL]
    params = model.init(jax.random.key(1))
    runs = [_e2e_rep(model, params, n_req, qps) for _ in range(max(3, reps))]
    p50 = min(r[0] for r in runs)
    p99 = min(r[1] for r in runs)
    sustained = max(r[2] for r in runs)
    tag = f"{MODEL}_q{qps:g}"
    return [
        entry(f"serve/e2e_p50_{tag}", p50, f"n{n_req}_per_run",
              reps=max(3, reps)),
        entry(f"serve/e2e_p99_{tag}", p99, f"n{n_req}_per_run",
              reps=max(3, reps)),
        entry(f"serve/sustained_qps_{tag}", 0.0,
              f"{sustained:.0f}_req_per_s_offered{qps:g}"),
    ]


def _decode_entries(batch: int, prompt_len: int, n_new: int,
                    reps: int) -> list[dict]:
    cfg = configs.reduced(configs.get(ARCH))
    params = tf.init_params(cfg, jax.random.key(0))
    adapter = LMAdapter(cfg, batch, prompt_len, n_new)
    prompts, _ = make_lm_tokens(cfg.vocab, batch, prompt_len, seed=1)
    stack = np.asarray(prompts, np.int32)

    us = measure(lambda: adapter.infer(params, stack), reps)
    toks = batch * n_new
    return [entry(f"serve/decode_{ARCH}_b{batch}_n{n_new}", us,
                  f"{toks / (us / 1e6):.0f}_tok_per_s", reps=reps)]


def entries(quick: bool = False) -> list[dict]:
    if quick:
        reps, n_req, qps, n_new = 3, 120, 150.0, 8
    else:
        reps, n_req, qps, n_new = 5, 400, 200.0, 16
    out = _classifier_entries(reps)
    out += _e2e_entries(n_req, qps, reps)
    out += _decode_entries(4, 16, n_new, reps)
    return out


def rows(quick: bool = False) -> list[tuple]:
    """Legacy ``(name, us_per_call, derived)`` tuples for the CSV printer."""
    return [(e["name"], e["us_per_call"], e["derived"])
            for e in entries(quick=quick)]
