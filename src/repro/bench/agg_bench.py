"""Aggregation-engine microbenchmarks (suite key ``agg`` -> BENCH_agg.json).

The canonical implementation of what ``benchmarks/bench_agg.py`` measured
(that module is now a thin shim over this one): one secure-aggregation round
for a single leaf at ``n_clients`` simulated clients —

  * ``loop``    — the seed implementation shape: an un-jitted Python loop that
    encodes one client at a time and scatter-adds one stream at a time.
  * ``batched`` — the stream engine (core/streams.py): every client encoded in
    one vmapped+jitted program, one fused scatter-add for the whole round.

plus kernel-level micro timings for the two data-plane primitives the sharded
round leans on: the counter-based pair-mask PRNG
(``kernels.ops.pair_mask_streams``) and the fused scatter-add decode
(``kernels.ops.stream_scatter_add`` / XLA scatter fallback).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.timing import entry, measure
from repro.core import streams
from repro.core.masks import client_masks
from repro.core.secure_agg import encode_leaf
from repro.core.types import SecureAggConfig, THGSConfig


def _loop_round(grads, residuals, k, thgs, sa, participants, size):
    """The seed path: per-client Python encode loop + per-client scatter."""
    C = len(participants)
    k_mask = sa.k_mask_for(size, C)
    streams_all = []
    for ci, c in enumerate(participants):
        mask = client_masks(sa, c, participants, 0, 0, size, k_mask)
        enc = encode_leaf(grads[ci], residuals[ci], k, thgs, mask)
        streams_all.append(enc.stream)
    dense = jnp.zeros((size,), jnp.float32)
    for s in streams_all:
        dense = dense.at[s.indices].add(s.values / C)
    return dense.block_until_ready()


def _one_size(size: int, n_clients: int, reps: int) -> list[dict]:
    k = max(1, size // 100)
    thgs = THGSConfig(s0=0.01, alpha=1.0, s_min=0.01, time_varying=False)
    sa = SecureAggConfig(mask_ratio=0.01, seed=7)
    participants = list(range(n_clients))
    key = jax.random.key(0)
    grads = jax.random.normal(key, (n_clients, size))
    residuals = jnp.zeros_like(grads)
    k_mask = sa.k_mask_for(size, n_clients)
    # the production data plane: counter-based pair seeds (repro/secagg),
    # not the legacy jax.random pair_keys path
    pair_seeds, pair_signs = streams.pair_seed_matrix(sa, participants, 0)

    def batched_round():
        st, _ = streams.encode_leaf_batch(
            grads, residuals, k=k, nb=1, m=size, size=size,
            pair_seeds=pair_seeds, pair_signs=pair_signs, k_mask=k_mask,
            mask_p=sa.p, mask_q=sa.q, leaf_id=0)
        return streams.decode_leaf_batch(
            st, nb=1, m=size, size=size).block_until_ready()

    us_loop = measure(lambda: _loop_round(grads, residuals, k, thgs, sa,
                                          participants, size), reps)
    us_batched = measure(batched_round, reps)

    k_total = k + n_clients * k_mask
    stream_mb = n_clients * k_total * 8 / 1e6          # int32 idx + f32 val
    dense_mb = n_clients * size * 4 / 1e6
    tag = f"c{n_clients}_n{size}"
    return [
        entry(f"agg/loop_{tag}", us_loop,
              f"{n_clients / (us_loop / 1e6):.0f}_clients_per_s", reps=reps),
        entry(f"agg/batched_{tag}", us_batched,
              f"{n_clients / (us_batched / 1e6):.0f}_clients_per_s",
              reps=reps),
        entry(f"agg/speedup_{tag}", 0.0, f"{us_loop / us_batched:.1f}x"),
        entry(f"agg/bytes_{tag}", 0.0,
              f"sparse{stream_mb:.1f}MB_vs_dense{dense_mb:.0f}MB"),
    ]


def _kernel_micro(size: int, n_clients: int, reps: int) -> list[dict]:
    """The two data-plane primitives, isolated."""
    from repro.kernels import ops

    sa = SecureAggConfig(mask_ratio=0.01, seed=7)
    k_mask = max(1, sa.k_mask_for(size, n_clients))
    seeds = jnp.arange(1, n_clients * n_clients + 1, dtype=jnp.uint32)
    signs = jnp.ones((n_clients * n_clients,), jnp.float32)

    def prng():
        idx, vals = ops.pair_mask_streams(
            seeds, signs, nb=1, k_mask=k_mask, m=size, p=sa.p, q=sa.q)
        return vals.block_until_ready()

    n_slots = n_clients * (max(1, size // 100) + n_clients * k_mask)
    key = jax.random.key(1)
    flat_idx = jax.random.randint(key, (n_slots,), 0, size, dtype=jnp.int32)
    flat_val = jax.random.normal(key, (n_slots,), jnp.float32)

    def scatter():
        return streams._scatter_flat(
            flat_idx, flat_val, size,
            jax.default_backend() == "tpu").block_until_ready()

    tag = f"c{n_clients}_n{size}"
    us_prng = measure(prng, reps)
    us_scatter = measure(scatter, reps)
    return [
        entry(f"agg/mask_prng_{tag}", us_prng,
              f"{n_clients * n_clients * k_mask}_slots", reps=reps),
        entry(f"agg/scatter_add_{tag}", us_scatter,
              f"{n_slots}_slots", reps=reps),
    ]


def _codec_micro(size: int, n_clients: int, reps: int) -> list[dict]:
    """Encode/decode throughput per wire codec (core/codecs.py, §12).

    ``enc`` is the full leaf encode (top-k + quantize + residual absorption +
    the in-trace packed-wire round trip for non-f32); ``dec`` is the round
    decode of the resulting streams. f32 is the passthrough baseline, so the
    enc ratios show what the quantize+bitpack stage itself costs.
    """
    from repro.core.codecs import CODECS

    k = max(1, size // 100)
    key = jax.random.key(2)
    grads = jax.random.normal(key, (n_clients, size))
    residuals = jnp.zeros_like(grads)
    tag = f"c{n_clients}_n{size}"
    out = []
    for codec in CODECS:
        def enc(_c=codec):
            st, nr = streams.encode_leaf_batch(
                grads, residuals, k=k, nb=1, m=size, size=size, codec=_c)
            return st.values.block_until_ready()

        st, _ = streams.encode_leaf_batch(
            grads, residuals, k=k, nb=1, m=size, size=size, codec=codec)

        def dec(_st=st):
            return streams.decode_leaf_batch(
                _st, nb=1, m=size, size=size).block_until_ready()

        us_enc = measure(enc, reps)
        us_dec = measure(dec, reps)
        slots = n_clients * k
        out += [
            entry(f"agg/codec_enc_{codec}_{tag}", us_enc,
                  f"{slots / (us_enc / 1e6) / 1e6:.1f}_Mslots_per_s",
                  reps=reps),
            entry(f"agg/codec_dec_{codec}_{tag}", us_dec,
                  f"{slots / (us_dec / 1e6) / 1e6:.1f}_Mslots_per_s",
                  reps=reps),
        ]
    return out


def entries(quick: bool = False) -> list[dict]:
    # headline: the paper-model regime (financial MLP/VGG leaves, 64k params);
    # the second size shows the top-k-bound tail where both paths converge on
    # the same sort cost
    if quick:
        return (_one_size(1 << 14, 8, reps=2)
                + _kernel_micro(1 << 14, 8, reps=3)
                + _codec_micro(1 << 14, 8, reps=2))
    out = _one_size(1 << 16, 32, reps=3)
    out += _one_size(1 << 20, 32, reps=2)
    out += _kernel_micro(1 << 16, 32, reps=5)
    out += _codec_micro(1 << 16, 32, reps=3)
    return out


def rows(quick: bool = False) -> list[tuple]:
    """Legacy ``(name, us_per_call, derived)`` tuples for the CSV printer."""
    return [(e["name"], e["us_per_call"], e["derived"])
            for e in entries(quick=quick)]
