"""BENCH_*.json schema + the CI regression gate. No jax imports here.

Document shape (one suite per file — the committed baselines — or several
under ``suites`` when ``--out`` collects one combined document, as the CI
smoke run does)::

    {
      "schema": "repro.bench/v1",
      "suite": "round",              # single-suite form
      "quick": true,
      "created_unix": 1753776000.0,
      "env": {"backend": "cpu", "device_count": 8,
              "jax": "0.4.37", "python": "3.11.8", "platform": "linux"},
      "entries": [
        {"name": "round/serial_c8_mnist_mlp",
         "us_per_call": 12345.6,      # 0.0 marks an info-only row
         "reps": 3,
         "derived": "3.1x_vs_serial"} # free-form context, string
      ]
    }

    {"schema": "...", "quick": true, "env": {...},
     "suites": {"round": [...entries], "agg": [...entries]}}

Entry names are stable identifiers: they encode the workload (suite, client
count, size, device count), so quick and full runs never collide and the gate
only ever compares like against like.
"""
from __future__ import annotations

import json
import platform
import sys
import time
from typing import Iterable, Sequence

SCHEMA_VERSION = "repro.bench/v1"

# gate defaults: generous — CI runners are noisy and share cores
DEFAULT_MAX_SLOWDOWN = 3.0
# entries faster than this are timer noise; never gate on them
DEFAULT_MIN_US = 20.0


def env_info() -> dict:
    """Runtime fingerprint stamped into every document (lazy jax import)."""
    info = {
        "python": platform.python_version(),
        "platform": sys.platform,
    }
    try:
        import jax

        info["jax"] = jax.__version__
        info["backend"] = jax.default_backend()
        info["device_count"] = len(jax.devices())
    except Exception:  # gate-only invocations never initialize a backend
        info["jax"] = None
        info["backend"] = None
        info["device_count"] = None
    return info


def make_doc(entries: list[dict], *, suite: str | None = None,
             suites: dict[str, list[dict]] | None = None,
             quick: bool = False) -> dict:
    """A schema'd document for one suite (``suite=``) or several
    (``suites=``, the ``--out`` combined form)."""
    assert (suite is None) != (suites is None), "exactly one of suite/suites"
    doc = {
        "schema": SCHEMA_VERSION,
        "quick": bool(quick),
        # intentional epoch stamp (doc metadata, not a timed duration)
        "created_unix": time.time(),  # repro-lint: disable=RPL001
        "env": env_info(),
    }
    if suite is not None:
        doc["suite"] = suite
        doc["entries"] = entries
    else:
        doc["suites"] = suites
    return doc


def validate_doc(doc: dict) -> list[str]:
    """Schema errors ([] = valid). Checked by tests and before every gate."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return ["document is not an object"]
    if doc.get("schema") != SCHEMA_VERSION:
        errs.append(f"schema must be {SCHEMA_VERSION!r}, "
                    f"got {doc.get('schema')!r}")
    if not isinstance(doc.get("env"), dict):
        errs.append("missing env object")
    single = "entries" in doc
    multi = "suites" in doc
    if single == multi:
        errs.append("need exactly one of 'entries' (with 'suite') "
                    "or 'suites'")
        return errs
    if single and not isinstance(doc.get("suite"), str):
        errs.append("'entries' form needs a string 'suite'")
    groups = ({doc.get("suite", "?"): doc["entries"]} if single
              else doc["suites"])
    if not isinstance(groups, dict):
        return errs + ["'suites' must be an object"]
    for sname, entries in groups.items():
        if not isinstance(entries, list) or not entries:
            errs.append(f"suite {sname!r}: entries must be a non-empty list")
            continue
        seen = set()
        for e in entries:
            name = e.get("name") if isinstance(e, dict) else None
            if not isinstance(name, str) or not name:
                errs.append(f"suite {sname!r}: entry without a name: {e!r}")
                continue
            if name in seen:
                errs.append(f"suite {sname!r}: duplicate entry {name!r}")
            seen.add(name)
            us = e.get("us_per_call")
            if not isinstance(us, (int, float)) or us < 0:
                errs.append(f"{name}: us_per_call must be a number >= 0")
            if "derived" in e and not isinstance(e["derived"], str):
                errs.append(f"{name}: derived must be a string")
    return errs


def iter_entries(doc: dict) -> Iterable[dict]:
    """Entries of either document form, flattened."""
    if "entries" in doc:
        yield from doc["entries"]
    for entries in doc.get("suites", {}).values():
        yield from entries


def load_doc(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    errs = validate_doc(doc)
    if errs:
        raise ValueError(f"{path}: invalid bench document: " + "; ".join(errs))
    return doc


def gate_compare(current: dict, baselines: Sequence[dict], *,
                 max_slowdown: float = DEFAULT_MAX_SLOWDOWN,
                 min_us: float = DEFAULT_MIN_US) -> tuple[list[str], int]:
    """Compare a fresh run against the committed baselines.

    Matches entries by name; an entry regresses when
    ``current > max_slowdown * baseline`` and the baseline is above the
    ``min_us`` noise floor. Info rows (``us_per_call == 0``) never gate.
    Returns ``(failure_lines, n_compared)`` — the caller must also fail when
    ``n_compared == 0`` (a vacuous gate means the baseline is stale, e.g.
    quick entries compared against a full-mode baseline).
    """
    base_by_name: dict[str, float] = {}
    for doc in baselines:
        for e in iter_entries(doc):
            base_by_name[e["name"]] = float(e["us_per_call"])
    failures: list[str] = []
    compared = 0
    for e in iter_entries(current):
        name = e["name"]
        cur = float(e["us_per_call"])
        base = base_by_name.get(name)
        if base is None or cur == 0.0 or base == 0.0:
            continue
        compared += 1
        if base < min_us:
            continue
        if cur > max_slowdown * base:
            failures.append(
                f"{name}: {cur:.1f}us vs baseline {base:.1f}us "
                f"({cur / base:.2f}x > {max_slowdown:.1f}x)")
    return failures, compared
