"""Federated-round benchmarks (suite key ``round`` -> BENCH_round.json).

Times one full ``core.fedavg.run_round`` — local SGD for the whole cohort,
THGS encode, pair-mask PRNG, fused scatter-add decode, server update — in
three configurations:

  * ``serial``  — the single-device vmap path (``mesh=None``);
  * ``sharded`` — the client-parallel path (DESIGN.md §11): the cohort
    partitioned over a 1-D ``clients`` device mesh, present only when the
    process has a usable multi-device mesh (the CLI forces 8 host devices on
    CPU so CI-quick always exercises it);
  * a secure-aggregation **dropout** round of each (Bonawitz recovery on the
    hot path).

Sharded and serial rounds are bit-exact, so the delta between their entries
is pure execution cost — the number the perf trajectory tracks PR over PR.
All entries are min-of-reps (``timing.measure``): at reps=2 a single OS
scheduler stall in a mean would trip the CI gate's 3x bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.timing import entry, measure
from repro.core import fedavg
from repro.core.types import FedConfig, SecureAggConfig, THGSConfig


def _setup(n_clients: int, local_steps: int, batch: int, seed: int = 0):
    from repro.models.paper_models import PAPER_MODELS, cross_entropy_loss

    model = PAPER_MODELS["mnist_mlp"]
    loss_fn = cross_entropy_loss(model)
    params = model.init(jax.random.key(seed))
    key = jax.random.key(seed + 1)
    x = jax.random.normal(key, (n_clients, local_steps, batch, 784),
                          jnp.float32)
    y = jax.random.randint(key, (n_clients, local_steps, batch), 0, 10)
    batches = {c: (x[c], y[c]) for c in range(n_clients)}
    fed = FedConfig(n_clients=n_clients, clients_per_round=n_clients,
                    local_steps=local_steps, local_batch=batch,
                    local_lr=0.05, rounds=100)
    # time_varying=False pins the k schedule: every timed call compiles once
    thgs = THGSConfig(s0=0.05, alpha=0.9, s_min=0.01, time_varying=False)
    sa = SecureAggConfig(mask_ratio=0.01, seed=11)
    return model, loss_fn, params, batches, fed, thgs, sa


def _round_timer(params, batches, loss_fn, fed, thgs, sa, *, mesh,
                 dropped=()):
    def call():
        state = fedavg.init_state(params, fed)
        state = fedavg.run_round(state, batches, loss_fn, fed, thgs, sa,
                                 dropped=dropped, mesh=mesh)
        jax.block_until_ready(
            jax.tree_util.tree_leaves(state.params))
        return state

    return call


def entries(quick: bool = False) -> list[dict]:
    from repro.launch.mesh import clients_mesh_for

    if quick:
        C, steps, batch, reps = 8, 2, 32, 2
    else:
        C, steps, batch, reps = 32, 5, 50, 3
    _, loss_fn, params, batches, fed, thgs, sa = _setup(C, steps, batch)
    mesh = clients_mesh_for(C)
    n_dev = mesh.devices.size if mesh is not None else 1
    model_size = sum(x.size for x in jax.tree_util.tree_leaves(params))
    dropped = tuple(range(max(1, C // 4)))   # recoverable: threshold=0.6

    out = [entry(f"round/model_size_c{C}", 0.0,
                 f"{model_size}_params_mnist_mlp")]
    us_serial = measure(
        _round_timer(params, batches, loss_fn, fed, thgs, sa, mesh=None),
        reps)
    out.append(entry(f"round/serial_c{C}", us_serial,
                     f"{C / (us_serial / 1e6):.0f}_clients_per_s", reps=reps))
    us_serial_drop = measure(
        _round_timer(params, batches, loss_fn, fed, thgs, sa, mesh=None,
                     dropped=dropped), reps)
    out.append(entry(f"round/serial_dropout_c{C}", us_serial_drop,
                     f"{len(dropped)}_dropped", reps=reps))
    if mesh is None:
        out.append(entry(f"round/sharded_c{C}", 0.0,
                         "unavailable_single_device"))
        return out
    us_sharded = measure(
        _round_timer(params, batches, loss_fn, fed, thgs, sa, mesh=mesh),
        reps)
    out.append(entry(f"round/sharded_c{C}_d{n_dev}", us_sharded,
                     f"{C / (us_sharded / 1e6):.0f}_clients_per_s",
                     reps=reps))
    us_sharded_drop = measure(
        _round_timer(params, batches, loss_fn, fed, thgs, sa, mesh=mesh,
                     dropped=dropped), reps)
    out.append(entry(f"round/sharded_dropout_c{C}_d{n_dev}", us_sharded_drop,
                     f"{len(dropped)}_dropped", reps=reps))
    out.append(entry(f"round/speedup_c{C}_d{n_dev}", 0.0,
                     f"{us_serial / us_sharded:.2f}x_vs_serial"))
    return out


def rows(quick: bool = False) -> list[tuple]:
    """Legacy ``(name, us_per_call, derived)`` tuples for the CSV printer."""
    return [(e["name"], e["us_per_call"], e["derived"])
            for e in entries(quick=quick)]
