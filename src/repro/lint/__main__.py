"""CLI: ``python -m repro.lint [paths...] [--gate] [--format json]``.

Mirrors ``repro.bench``'s gate design: ``--gate`` exits 1 on any
unsuppressed finding — and on a vacuous run (no files linted), so a mistyped
path cannot silently pass CI.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Sequence

from repro.lint import core, report

DEFAULT_PATHS = ("src", "tests")


def _parse_ids(raw: str | None) -> set[str] | None:
    if not raw:
        return None
    return {s.strip() for s in raw.split(",") if s.strip()}


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.lint",
        description="AST-based invariant checks (DESIGN.md §14)",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        default=None,
        help=f"files/directories to lint (default: {' '.join(DEFAULT_PATHS)})",
    )
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None, help="write the report to a file")
    ap.add_argument(
        "--gate",
        action="store_true",
        help="exit 1 on any unsuppressed finding (or a vacuous run)",
    )
    ap.add_argument("--select", default=None, help="comma-separated check ids")
    ap.add_argument("--ignore", default=None, help="comma-separated check ids")
    ap.add_argument(
        "--show-suppressed",
        action="store_true",
        help="include suppressed findings in the text report",
    )
    ap.add_argument(
        "--list-checks", action="store_true", help="print the check catalogue"
    )
    args = ap.parse_args(argv)

    if args.list_checks:
        for check_id in sorted(core.CHECKS):
            check = core.CHECKS[check_id]
            print(f"{check_id}  {check.title}")
            print(f"        {check.rationale}")
        return 0

    paths = args.paths or list(DEFAULT_PATHS)
    select = _parse_ids(args.select)
    ignore = _parse_ids(args.ignore)
    unknown = (select or set()) | (ignore or set())
    unknown -= set(core.CHECKS) | {core.PARSE_ERROR_ID}
    if unknown:
        print(f"unknown check id(s): {sorted(unknown)}", file=sys.stderr)
        return 2

    findings, n_files = core.lint_paths(paths, select=select, ignore=ignore)
    active = [f for f in findings if not f.suppressed]

    if args.format == "json":
        text = json.dumps(report.make_doc(findings, n_files, paths), indent=1)
    else:
        text = report.render_text(
            findings, n_files, show_suppressed=args.show_suppressed
        )
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            f.write(text + "\n")
    else:
        print(text)

    if args.gate:
        if n_files == 0:
            print("gate FAILED: no files linted (vacuous gate)", file=sys.stderr)
            return 1
        if active:
            print(f"gate FAILED: {len(active)} finding(s)", file=sys.stderr)
            return 1
        print(f"gate OK: {n_files} file(s) clean", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
