"""Reporters and the ``repro.lint/v1`` JSON document (mirrors repro.bench).

``make_doc`` emits a machine-readable run summary; ``validate_doc`` returns
a list of schema violations (empty == valid) so tests and CI can round-trip
the document exactly like the BENCH_*.json suites do.
"""

from __future__ import annotations

import re
from typing import Iterable, Sequence

from repro.lint.core import CHECKS, Finding

SCHEMA_VERSION = "repro.lint/v1"

_CHECK_ID_RE = re.compile(r"^RPL\d{3}$")
_FINDING_FIELDS = {
    "check": str,
    "path": str,
    "line": int,
    "col": int,
    "message": str,
}


def _finding_dict(f: Finding) -> dict:
    return {
        "check": f.check,
        "path": f.path,
        "line": f.line,
        "col": f.col,
        "message": f.message,
    }


def make_doc(findings: Sequence[Finding], n_files: int, paths: Sequence[str]) -> dict:
    """Build one schema'd document from a lint run."""
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    counts: dict[str, int] = {}
    for f in active:
        counts[f.check] = counts.get(f.check, 0) + 1
    return {
        "schema": SCHEMA_VERSION,
        "paths": [str(p) for p in paths],
        "files": int(n_files),
        "checks": sorted(CHECKS),
        "findings": [_finding_dict(f) for f in active],
        "suppressed": [_finding_dict(f) for f in suppressed],
        "counts": counts,
    }


def validate_doc(doc: object) -> list[str]:
    """Schema errors for ``doc`` (empty list == valid)."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["document is not an object"]
    if doc.get("schema") != SCHEMA_VERSION:
        errors.append(f"schema must be {SCHEMA_VERSION!r}, got {doc.get('schema')!r}")
    if not isinstance(doc.get("files"), int) or doc.get("files", -1) < 0:
        errors.append("files must be a non-negative int")
    if not isinstance(doc.get("paths"), list):
        errors.append("paths must be a list")
    for section in ("findings", "suppressed"):
        items = doc.get(section)
        if not isinstance(items, list):
            errors.append(f"{section} must be a list")
            continue
        for i, item in enumerate(items):
            errors.extend(_validate_finding(f"{section}[{i}]", item))
    counts = doc.get("counts")
    if not isinstance(counts, dict):
        errors.append("counts must be an object")
    elif isinstance(doc.get("findings"), list):
        derived: dict[str, int] = {}
        for item in doc["findings"]:
            if isinstance(item, dict) and isinstance(item.get("check"), str):
                derived[item["check"]] = derived.get(item["check"], 0) + 1
        if counts != derived:
            errors.append(f"counts {counts} do not match findings {derived}")
    return errors


def _validate_finding(where: str, item: object) -> list[str]:
    if not isinstance(item, dict):
        return [f"{where} is not an object"]
    errors = []
    for field, typ in _FINDING_FIELDS.items():
        if not isinstance(item.get(field), typ):
            errors.append(f"{where}.{field} must be {typ.__name__}")
    check = item.get("check")
    if isinstance(check, str) and not _CHECK_ID_RE.match(check):
        errors.append(f"{where}.check {check!r} is not an RPLxxx id")
    return errors


def render_text(
    findings: Iterable[Finding], n_files: int, *, show_suppressed: bool = False
) -> str:
    """Human-readable report: one ``path:line:col: ID message`` per finding."""
    lines = []
    n_active = 0
    n_suppressed = 0
    for f in findings:
        if f.suppressed:
            n_suppressed += 1
            if show_suppressed:
                lines.append(f"{f.location()}: {f.check} [suppressed] {f.message}")
        else:
            n_active += 1
            lines.append(f"{f.location()}: {f.check} {f.message}")
    summary = (
        f"{n_files} file(s) checked: {n_active} finding(s), "
        f"{n_suppressed} suppressed"
    )
    lines.append(summary)
    return "\n".join(lines)
