"""repro.lint — AST-based invariant checks for the repro codebase.

The type system sees none of the invariants this codebase actually rests
on: bit-exact cross-process replay, pair-mask cancellation that only holds
on the f32 2^-24 grid, min-of-reps bench timing, the concatenation combine
of the tree decode, and the Pallas kernel-twin contract.  ``repro.lint``
codifies each known bug class as a named, testable static check
(DESIGN.md §14 is the catalogue):

========  ==============================================================
RPL001    nondeterminism sources (hash(), time.time(), stdlib random,
          argless datetime.now(), set iteration order)
RPL002    bench suites timing outside ``timing.measure`` (min-of-reps)
RPL003    codec x secagg entry points missing the shared non-f32 guard
RPL004    non-associative (psum-style) combines in decode modules
RPL005    pallas_call wrappers without a kernels/ref.py twin or
          interpret fallback
RPL006    Python branching on traced values inside ``@jit`` functions
RPL007    json.dump to a non-tmp path (crash leaves a truncated file;
          the discipline is dump to path + '.tmp' then os.replace)
========  ==============================================================

``python -m repro.lint src tests --gate`` runs the suite and exits
non-zero on any unsuppressed finding (CI runs it before tier-1); findings
are suppressed per line with ``# repro-lint: disable=RPLxxx``.

Import discipline: like ``repro.bench``, this package imports no jax — the
gate runs without touching a backend.
"""

from __future__ import annotations

from repro.lint import bench_checks as _bench_checks
from repro.lint import determinism as _determinism
from repro.lint import io_checks as _io_checks
from repro.lint import kernel_checks as _kernel_checks
from repro.lint import secagg_checks as _secagg_checks
from repro.lint.core import (
    CHECKS,
    PARSE_ERROR_ID,
    Check,
    Finding,
    LintContext,
    SourceFile,
    iter_python_files,
    lint_file,
    lint_paths,
    lint_source,
    register,
)
from repro.lint.report import SCHEMA_VERSION, make_doc, render_text, validate_doc

del _bench_checks, _determinism, _io_checks, _kernel_checks, _secagg_checks

__all__ = [
    "CHECKS",
    "Check",
    "Finding",
    "LintContext",
    "PARSE_ERROR_ID",
    "SCHEMA_VERSION",
    "SourceFile",
    "iter_python_files",
    "lint_file",
    "lint_paths",
    "lint_source",
    "make_doc",
    "register",
    "render_text",
    "validate_doc",
]
