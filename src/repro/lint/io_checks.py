"""RPL007 — non-atomic JSON writes to checkpoint/sidecar/ledger paths.

A crash (or a kill -9) between ``open(path, "w")`` and the final flush
leaves a *truncated but present* JSON file.  For checkpoint manifests and
sim sidecars that is worse than no file at all: resume logic that picks the
newest pair by existence then dies inside ``json.load`` instead of falling
back to the previous good checkpoint — exactly the bug fixed in
``checkpoint/store.py`` and ``sim/engine.py``.  The repo-wide discipline is
therefore *tmp + os.replace*: dump into ``path + ".tmp"`` and atomically
rename over the target.

RPL007 flags any ``json.dump(obj, f)`` where ``f`` comes from a
``with open(path, "w")`` whose path expression is not tmp-like (no
``".tmp"`` component in the literal, f-string, concatenation, or the simple
assignment the name resolves to).  Test files are exempt — tests write
throwaway JSON (and deliberately truncated fixtures) all the time.
"""

from __future__ import annotations

import ast
import posixpath
from typing import Iterator, Optional

from repro.lint.core import Check, Finding, LintContext, SourceFile, register
from repro.lint.determinism import _call_name


def _expr_is_tmp_like(node: ast.AST, assigns: dict[str, ast.AST],
                      depth: int = 0) -> bool:
    """Does the path expression visibly carry a ``.tmp`` component?"""
    if depth > 8:
        return False
    if isinstance(node, ast.Constant):
        return isinstance(node.value, str) and ".tmp" in node.value
    if isinstance(node, ast.JoinedStr):
        return any(
            isinstance(v, ast.Constant) and isinstance(v.value, str)
            and ".tmp" in v.value
            for v in node.values
        )
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        return (_expr_is_tmp_like(node.left, assigns, depth + 1)
                or _expr_is_tmp_like(node.right, assigns, depth + 1))
    if isinstance(node, ast.Name) and node.id in assigns:
        return _expr_is_tmp_like(assigns[node.id], assigns, depth + 1)
    return False


def _open_write_target(item: ast.withitem) -> Optional[tuple[ast.AST, str]]:
    """``(path_expr, as_name)`` when the withitem is ``open(path, "w"...)``."""
    call = item.context_expr
    if not isinstance(call, ast.Call) or _call_name(call.func) != "open":
        return None
    if not call.args:
        return None
    mode = None
    if len(call.args) >= 2:
        mode = call.args[1]
    for kw in call.keywords:
        if kw.arg == "mode":
            mode = kw.value
    if not (isinstance(mode, ast.Constant) and isinstance(mode.value, str)
            and "w" in mode.value):
        return None
    if item.optional_vars is None or not isinstance(item.optional_vars,
                                                    ast.Name):
        return None
    return call.args[0], item.optional_vars.id


@register
class NonAtomicJsonDump(Check):
    id = "RPL007"
    title = "json.dump to a non-tmp path without the tmp + os.replace idiom"
    rationale = (
        "a crash mid-dump leaves a truncated-but-present JSON file that "
        "shadows the last good checkpoint/sidecar/ledger; dumping to "
        "path + '.tmp' then os.replace() makes the write atomic"
    )

    def applies(self, src: SourceFile) -> bool:
        name = posixpath.basename(src.path)
        return not (name.startswith("test_") or "/tests/" in src.path
                    or src.path.startswith("tests/"))

    def run(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        # simple `name = expr` assignments anywhere in the file, for
        # resolving `tmp = path + ".tmp"` through the open() argument
        assigns: dict[str, ast.AST] = {}
        for sub in ast.walk(src.tree):
            if (isinstance(sub, ast.Assign) and len(sub.targets) == 1
                    and isinstance(sub.targets[0], ast.Name)):
                assigns[sub.targets[0].id] = sub.value
        for w in ast.walk(src.tree):
            if isinstance(w, ast.With):
                yield from self._check_with(src, w, assigns)

    def _check_with(self, src: SourceFile, w: ast.With,
                    assigns: dict[str, ast.AST]) -> Iterator[Finding]:
        for item in w.items:
            target = _open_write_target(item)
            if target is None:
                continue
            path_expr, as_name = target
            if _expr_is_tmp_like(path_expr, assigns):
                continue
            for sub in ast.walk(w):
                if not isinstance(sub, ast.Call):
                    continue
                if _call_name(sub.func) != "dump":
                    continue
                fileobj = None
                if len(sub.args) >= 2:
                    fileobj = sub.args[1]
                for kw in sub.keywords:
                    if kw.arg == "fp":
                        fileobj = kw.value
                if (isinstance(fileobj, ast.Name)
                        and fileobj.id == as_name):
                    yield self.finding(
                        src,
                        sub,
                        "json.dump into open(..., 'w') on a non-tmp path — "
                        "a crash mid-write leaves a truncated JSON shadowing "
                        "the last good file; dump to path + '.tmp' and "
                        "os.replace() it over the target (DESIGN.md §14)",
                    )
