"""RPL001/RPL006 — nondeterminism sources and traced-value branching.

RPL001 codifies the PR-5 bug class: ``data/datasets.py`` once seeded its
class prototypes from builtin ``hash()``, which is salted per process
(``PYTHONHASHSEED``), so identical runs produced different accuracies across
invocations.  The check flags every statically recognizable source of
cross-process nondeterminism: builtin ``hash()``, wall-clock ``time.time()``
(use ``time.perf_counter()`` for durations; suppress for intentional epoch
stamps), argless ``datetime.now()``/``today()``/``utcnow()``, the
process-global stdlib ``random`` module (counter-based RNG — ``jax.random``
or seeded ``np.random.RandomState`` — is the sanctioned source), and
iteration-order dependence on sets (``for x in set(...)``, ``list(set(...))``
— wrap in ``sorted()``).

RPL006 flags Python-level branching on traced values inside ``@jit``-deco-
rated functions: an ``if``/``while``/ternary whose test uses a non-static
parameter as a boolean or comparison operand fails at trace time (or, worse,
silently bakes in the tracer's shape-dependent answer).  ``x is None`` /
``x is not None`` and attribute tests (``x.ndim == 3``) are static at trace
time and pass.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint.core import Check, Finding, LintContext, SourceFile, register

_DATETIME_NOW = {"now", "today", "utcnow"}
_ORDERED_CONSUMERS = {"list", "tuple", "enumerate", "iter"}

_HASH_MSG = (
    "builtin hash() is salted per process (PYTHONHASHSEED) — the PR-5 "
    "prototype-seeding bug; use zlib.crc32 or hashlib for a stable digest"
)
_TIME_MSG = (
    "wall-clock time.time() is nondeterministic; use time.perf_counter() "
    "for durations, or suppress for an intentional epoch stamp"
)
_RANDOM_MSG = (
    "stdlib random draws from process-global state; use counter-based RNG "
    "(jax.random / seeded np.random.RandomState)"
)
_DATETIME_MSG = (
    "argless datetime.{attr}() reads the wall clock; pass an explicit "
    "timestamp in"
)
_SET_ORDER_MSG = "set iteration order is unstable across processes; wrap in sorted(...)"


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False


def _call_name(func: ast.AST) -> str:
    """Rightmost name of a call target: ``a.b.c(...)`` -> ``'c'``."""
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return ""


@register
class NondeterminismSources(Check):
    id = "RPL001"
    title = "nondeterminism source in seed/sim path"
    rationale = (
        "bit-exact cross-process replay is a stated contract (DESIGN.md §9); "
        "salted hash()/wall clocks/global random/set order silently break it"
    )

    def run(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        random_names = self._stdlib_random_imports(src.tree)
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(src, node, random_names)
            elif isinstance(node, (ast.For, ast.comprehension)):
                if _is_set_expr(node.iter):
                    yield self.finding(src, node.iter, _SET_ORDER_MSG)

    @staticmethod
    def _stdlib_random_imports(tree: ast.Module) -> set[str]:
        """Names bound to the stdlib ``random`` module or its members."""
        names: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "random":
                        names.add(alias.asname or "random")
            elif isinstance(node, ast.ImportFrom):
                if node.module == "random" and node.level == 0:
                    for alias in node.names:
                        names.add(alias.asname or alias.name)
        return names

    def _check_call(
        self, src: SourceFile, node: ast.Call, random_names: set[str]
    ) -> Iterator[Finding]:
        func = node.func
        if isinstance(func, ast.Name):
            if func.id == "hash":
                yield self.finding(src, node, _HASH_MSG)
            elif func.id in random_names and func.id != "random":
                yield self.finding(src, node, _RANDOM_MSG)
            elif func.id in _ORDERED_CONSUMERS:
                if node.args and _is_set_expr(node.args[0]):
                    yield self.finding(src, node, _SET_ORDER_MSG)
        elif isinstance(func, ast.Attribute):
            base = func.value
            argless = not node.args and not node.keywords
            if isinstance(base, ast.Name):
                if base.id == "time" and func.attr == "time":
                    yield self.finding(src, node, _TIME_MSG)
                elif base.id in random_names:
                    yield self.finding(src, node, _RANDOM_MSG)
                elif base.id == "datetime" and func.attr in _DATETIME_NOW:
                    if argless:
                        msg = _DATETIME_MSG.format(attr=func.attr)
                        yield self.finding(src, node, msg)
            elif func.attr == "join" and node.args and _is_set_expr(node.args[0]):
                yield self.finding(src, node, _SET_ORDER_MSG)
            elif func.attr in _DATETIME_NOW and isinstance(base, ast.Attribute):
                if base.attr == "datetime" and argless:
                    msg = _DATETIME_MSG.format(attr=func.attr)
                    yield self.finding(src, node, msg)


def _jit_decorator_statics(dec: ast.AST) -> tuple[bool, set[str], set[int]]:
    """Classify one decorator: ``(is_jit, static_argnames, static_argnums)``.

    Recognizes ``@jax.jit``, ``@jit``, and the repo idiom
    ``@[functools.]partial(jax.jit, static_argnames=(...))`` (plus
    ``static_argnums``); a jit applied at call sites (``jax.jit(fn)``) is
    out of static reach and documented as such in DESIGN.md §14.
    """

    def is_jit_name(node: ast.AST) -> bool:
        return (isinstance(node, ast.Name) and node.id == "jit") or (
            isinstance(node, ast.Attribute) and node.attr == "jit"
        )

    if is_jit_name(dec):
        return True, set(), set()
    if not isinstance(dec, ast.Call):
        return False, set(), set()
    is_partial = _call_name(dec.func) == "partial"
    if is_partial and dec.args and is_jit_name(dec.args[0]):
        pass  # @partial(jax.jit, ...)
    elif is_jit_name(dec.func):
        pass  # @jax.jit(...) factory form
    else:
        return False, set(), set()
    names: set[str] = set()
    nums: set[int] = set()
    for kw in dec.keywords:
        try:
            value = ast.literal_eval(kw.value)
        except (ValueError, SyntaxError):
            continue
        if kw.arg == "static_argnames":
            values = (value,) if isinstance(value, str) else value
            names.update(str(v) for v in values)
        elif kw.arg == "static_argnums":
            values = (value,) if isinstance(value, int) else value
            nums.update(int(v) for v in values)
    return True, names, nums


def _traced_params(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    is_jit = False
    static_names: set[str] = set()
    static_nums: set[int] = set()
    for dec in fn.decorator_list:
        jit, names, nums = _jit_decorator_statics(dec)
        is_jit = is_jit or jit
        static_names |= names
        static_nums |= nums
    if not is_jit:
        return set()
    positional = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    params = set(positional) | {a.arg for a in fn.args.kwonlyargs}
    params -= static_names | {"self", "cls"}
    params -= {positional[i] for i in static_nums if i < len(positional)}
    return params


def _bound_params(
    node: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda,
) -> set[str]:
    args = node.args
    return {a.arg for a in args.posonlyargs + args.args + args.kwonlyargs}


def _traced_bool_operands(test: ast.AST, traced: set[str]) -> list[ast.Name]:
    """Traced names used directly as boolean/comparison operands in a test."""
    out: list[ast.Name] = []

    def visit(e: ast.AST) -> None:
        if isinstance(e, ast.BoolOp):
            for v in e.values:
                visit(v)
        elif isinstance(e, ast.UnaryOp) and isinstance(e.op, ast.Not):
            visit(e.operand)
        elif isinstance(e, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in e.ops):
                return  # `x is [not] None`: static at trace time
            for operand in [e.left, *e.comparators]:
                if isinstance(operand, ast.Name) and operand.id in traced:
                    out.append(operand)
        elif isinstance(e, ast.Name) and e.id in traced:
            out.append(e)

    visit(test)
    return out


@register
class TracedBranching(Check):
    id = "RPL006"
    title = "Python branching on a traced value inside @jit"
    rationale = (
        "an if/while on a tracer either fails at trace time or bakes the "
        "tracer's answer into the compiled program; use lax.cond/jnp.where"
    )

    def run(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        for node in ast.walk(src.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            traced = _traced_params(node)
            if traced:
                yield from self._scan_body(src, node, traced)

    def _scan_body(
        self, src: SourceFile, node: ast.AST, traced: set[str]
    ) -> Iterator[Finding]:
        for child in ast.iter_child_nodes(node):
            scope = traced
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                scope = traced - _bound_params(child)
            if isinstance(child, (ast.If, ast.While, ast.IfExp)):
                for name in _traced_bool_operands(child.test, scope):
                    yield self.finding(
                        src,
                        name,
                        f"branch tests traced parameter {name.id!r} inside a "
                        "@jit function; hoist to static_argnames or use "
                        "lax.cond / jnp.where",
                    )
            yield from self._scan_body(src, child, scope)
