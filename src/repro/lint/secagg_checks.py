"""RPL003/RPL004 — secagg x codec guard and decode-combine invariants.

RPL003: sparse pair masks cancel bit-exactly only on the f32 2^-24 grid
(Beguier et al., arXiv 2007.14861; DESIGN.md §12), so every public entry
point that accepts both a ``codec`` and a secure-aggregation parameter must
route the combination through the one shared guard,
``repro.core.codecs.reject_codec_with_masks`` — scattered hand-rolled
``if codec != "f32"`` raises drift apart (and did, before this check).

RPL004: DESIGN.md §13 mandates the *concatenation* combine for the tree
decode — f32 addition is non-associative, and any ``psum``-style partial-sum
combine of per-group dense buffers silently breaks the tree==flat bit-parity
that every hierarchical-aggregation test relies on.  Scope: the decode
modules (``core/streams.py``, ``core/blocked.py``, ``kernels/*decode*``).
"""

from __future__ import annotations

import ast
import posixpath
from typing import Iterator

from repro.lint.core import Check, Finding, LintContext, SourceFile, register
from repro.lint.determinism import _call_name

GUARD_NAMES = {"reject_codec_with_masks", "_reject_codec_with_masks"}

#: parameters whose presence marks a secure-aggregation surface
MASK_PARAMS = {"sa", "k_mask", "k_masks", "pair_seeds", "pair_keys", "use_masks"}

_FORBIDDEN_COMBINES = {"psum", "psum_scatter", "all_reduce", "pmean"}

_DECODE_FILES = {"core/streams.py", "core/blocked.py"}


def _param_names(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    args = fn.args
    return {a.arg for a in args.posonlyargs + args.args + args.kwonlyargs}


@register
class CodecMaskGuard(Check):
    id = "RPL003"
    title = "codec x secagg entry point misses the shared rejection guard"
    rationale = (
        "quantized codecs off the f32 2^-24 grid break pair-mask "
        "cancellation; one shared guard keeps every layer's rejection "
        "identical"
    )

    def run(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        for node in ast.walk(src.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if node.name.startswith("_") or node.name in GUARD_NAMES:
                continue
            params = _param_names(node)
            if "codec" not in params or not (params & MASK_PARAMS):
                continue
            calls_guard = any(
                isinstance(sub, ast.Call) and _call_name(sub.func) in GUARD_NAMES
                for sub in ast.walk(node)
            )
            if not calls_guard:
                yield self.finding(
                    src,
                    node,
                    f"public entry point {node.name}() accepts 'codec' and a "
                    f"secagg parameter ({sorted(params & MASK_PARAMS)}) but "
                    "never calls codecs.reject_codec_with_masks — non-f32 "
                    "codecs must be rejected under masks (DESIGN.md §12)",
                )


@register
class DecodeCombine(Check):
    id = "RPL004"
    title = "non-associative reduction in a decode module"
    rationale = (
        "f32 addition is non-associative; DESIGN.md §13 mandates the "
        "concatenation combine so tree==flat stays bit-exact"
    )

    def applies(self, src: SourceFile) -> bool:
        if any(src.path.endswith(f) for f in _DECODE_FILES):
            return True
        name = posixpath.basename(src.path)
        return "decode" in name and name.endswith(".py")

    def run(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node.func)
            if name in _FORBIDDEN_COMBINES:
                yield self.finding(
                    src,
                    node,
                    f"{name}() combines partial sums in a decode module — "
                    "f32 addition is non-associative and breaks tree==flat "
                    "bit-parity; use the range-sharded concatenation combine "
                    "(DESIGN.md §13)",
                )
            elif name == "reduce" and node.args:
                first = node.args[0]
                if _call_name(first) == "add" or (
                    isinstance(first, ast.Attribute) and first.attr == "add"
                ):
                    yield self.finding(
                        src,
                        node,
                        "reduce(add, ...) over decode partials is order-"
                        "dependent in f32; use the concatenation combine "
                        "(DESIGN.md §13)",
                    )
