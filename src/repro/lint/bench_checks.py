"""RPL002 — bench suites must time through ``timing.measure``.

The CI perf gate compares every ``--quick`` run against committed
``BENCH_*.json`` baselines with a 3x slowdown bound; a mean over 2-3 reps of
a sub-millisecond op trips it on a single OS scheduler stall (PR 6 hit this
on the agg micro-entries).  ``timing.measure`` (min-of-reps) is the
canonical suite timer — this check replaces the ``measure(``/``time_us(``
source greps that used to live in ``tests/test_bench.py``.

Scope: ``*_bench.py`` modules under ``repro/bench/`` (``timing.py`` itself
is the sanctioned ``perf_counter`` call site and is out of scope).
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint.core import Check, Finding, LintContext, SourceFile, register
from repro.lint.determinism import _call_name


@register
class BenchTiming(Check):
    id = "RPL002"
    title = "bench suite times outside timing.measure"
    rationale = (
        "the 3x CI gate needs min-of-reps timings; raw perf_counter or "
        "mean-of-reps time_us trips it on one scheduler stall"
    )

    def applies(self, src: SourceFile) -> bool:
        if "repro/bench/" not in src.path:
            return False
        return src.path.endswith("_bench.py")

    def run(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        saw_measure = False
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node.func)
            if name == "measure":
                saw_measure = True
            elif name == "time_us":
                yield self.finding(
                    src,
                    node,
                    "suite times with mean-of-reps time_us(); use "
                    "timing.measure (min-of-reps)",
                )
            elif name == "perf_counter":
                yield self.finding(
                    src,
                    node,
                    "suite reads perf_counter directly; time through "
                    "timing.measure (min-of-reps)",
                )
        if not saw_measure:
            yield Finding(
                self.id,
                src.path,
                1,
                1,
                "bench suite never calls timing.measure — entries must be "
                "min-of-reps timings (tests/test_bench.py pins this)",
            )
