"""Visitor core for ``repro.lint`` — files, suppressions, registry, runner.

The framework mirrors ``repro.bench``'s design: pure stdlib (no jax import —
the CLI and the CI gate must run without touching a backend), a small
registry of named checks, and a machine-readable document format
(``repro.lint/v1``, see :mod:`repro.lint.report`) gated in CI.

Every check is AST-based: string literals, comments and docstrings are never
flagged, so tests can embed bad snippets as fixtures and modules can document
forbidden patterns freely.  Findings are suppressed per line with a trailing
``# repro-lint: disable=RPL001`` comment (or ``disable-next=`` on the line
above, or ``disable-file=`` anywhere in the file); suppressed findings stay
in the report but do not fail the ``--gate`` (DESIGN.md §14).
"""

from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import Iterable, Iterator

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable|disable-next|disable-file)=([A-Za-z0-9_,\s]+)"
)
_TWIN_RE = re.compile(r"#\s*repro-lint:\s*twin=([A-Za-z0-9_]+)")

PARSE_ERROR_ID = "RPL000"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding; ``suppressed`` findings never fail the gate."""

    check: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"


class SourceFile:
    """A parsed source file plus its suppression table."""

    def __init__(self, path: str, text: str):
        self.path = path.replace(os.sep, "/")
        self.text = text
        self.tree = ast.parse(text)  # raises SyntaxError -> RPL000 upstream
        self.line_suppress: dict[int, set[str]] = {}
        self.file_suppress: set[str] = set()
        self.twin_overrides: dict[int, str] = {}
        self._scan_comments()

    def _scan_comments(self) -> None:
        try:
            tokens = tokenize.generate_tokens(io.StringIO(self.text).readline)
            comments = [t for t in tokens if t.type == tokenize.COMMENT]
        except (tokenize.TokenError, IndentationError):  # pragma: no cover
            return
        for tok in comments:
            line = tok.start[0]
            twin = _TWIN_RE.search(tok.string)
            if twin:
                self.twin_overrides[line] = twin.group(1)
            m = _SUPPRESS_RE.search(tok.string)
            if not m:
                continue
            directive, raw = m.group(1), m.group(2)
            ids = {s.strip() for s in raw.split(",") if s.strip()}
            if directive == "disable-file":
                self.file_suppress |= ids
            elif directive == "disable-next":
                self.line_suppress.setdefault(line + 1, set()).update(ids)
            else:
                self.line_suppress.setdefault(line, set()).update(ids)

    def is_suppressed(self, check_id: str, line: int) -> bool:
        if check_id in self.file_suppress:
            return True
        return check_id in self.line_suppress.get(line, ())


class LintContext:
    """Shared cross-file state for one run (e.g. RPL005's ref-twin names)."""

    def __init__(self) -> None:
        self.cache: dict = {}


class Check:
    """Base class: subclass, set ``id``/``title``/``rationale``, register."""

    id = ""
    title = ""
    rationale = ""

    def applies(self, src: SourceFile) -> bool:
        return True

    def run(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, src: SourceFile, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0) + 1
        return Finding(self.id, src.path, line, col, message)


CHECKS: dict[str, Check] = {}


def register(cls: type[Check]) -> type[Check]:
    """Class decorator adding one check instance to the registry."""
    inst = cls()
    if not inst.id or inst.id in CHECKS:
        raise ValueError(f"bad or duplicate check id {inst.id!r}")
    CHECKS[inst.id] = inst
    return cls


def _selected(select: set[str] | None, ignore: set[str] | None) -> list[Check]:
    checks = [CHECKS[k] for k in sorted(CHECKS)]
    if select:
        checks = [c for c in checks if c.id in select]
    if ignore:
        checks = [c for c in checks if c.id not in ignore]
    return checks


def lint_source(
    text: str,
    path: str,
    *,
    select: set[str] | None = None,
    ignore: set[str] | None = None,
    ctx: LintContext | None = None,
) -> list[Finding]:
    """Lint one source string as if it lived at ``path``.

    Path-scoped checks (RPL002 bench suites, RPL004 decode modules, RPL005
    kernel modules) key off ``path``, so fixtures can exercise them without
    touching the real tree.
    """
    ctx = ctx if ctx is not None else LintContext()
    try:
        src = SourceFile(path, text)
    except SyntaxError as e:
        line = e.lineno or 1
        col = e.offset or 1
        msg = f"file does not parse: {e.msg}"
        return [Finding(PARSE_ERROR_ID, path.replace(os.sep, "/"), line, col, msg)]
    findings: list[Finding] = []
    for check in _selected(select, ignore):
        if not check.applies(src):
            continue
        for f in check.run(src, ctx):
            if src.is_suppressed(f.check, f.line):
                f = dataclasses.replace(f, suppressed=True)
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.check))
    return findings


def lint_file(
    path: str,
    *,
    select: set[str] | None = None,
    ignore: set[str] | None = None,
    ctx: LintContext | None = None,
) -> list[Finding]:
    """Lint one file on disk."""
    with open(path, encoding="utf-8") as f:
        text = f.read()
    return lint_source(text, path, select=select, ignore=ignore, ctx=ctx)


_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache", "node_modules"}


def iter_python_files(paths: Iterable[str]) -> Iterator[str]:
    """Yield ``.py`` files under each path (files pass through verbatim)."""
    for p in paths:
        if os.path.isfile(p):
            yield p
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(
                d for d in dirnames if d not in _SKIP_DIRS and not d.startswith(".")
            )
            for name in sorted(filenames):
                if name.endswith(".py"):
                    yield os.path.join(dirpath, name)


def lint_paths(
    paths: Iterable[str],
    *,
    select: set[str] | None = None,
    ignore: set[str] | None = None,
) -> tuple[list[Finding], int]:
    """Lint every python file under ``paths``; returns ``(findings, n_files)``."""
    ctx = LintContext()
    findings: list[Finding] = []
    n_files = 0
    for path in iter_python_files(paths):
        n_files += 1
        findings.extend(lint_file(path, select=select, ignore=ignore, ctx=ctx))
    return findings, n_files
