"""RPL005 — the Pallas kernel-twin contract (DESIGN.md §8/§14).

Every wrapper in ``kernels/`` that issues a ``pl.pallas_call`` must (a) have
a pure-jnp twin in ``kernels/ref.py`` — the bit-parity reference that the
kernel tests pin and that CPU/interpret environments fall back to — and
(b) accept an ``interpret`` parameter and forward it to ``pallas_call``, so
the same body runs without a TPU backend.

Twin resolution: for a wrapper ``name`` the check accepts ``name_ref``, the
``_apply``-stripped form (``mask_prng_apply`` -> ``mask_prng_ref``), and the
de-pluralized form (``pair_mask_streams`` -> ``pair_mask_stream_ref``); an
explicit ``# repro-lint: twin=<ref_name>`` comment on the ``def`` line
overrides the search.
"""

from __future__ import annotations

import ast
import posixpath
from typing import Iterator

from repro.lint.core import Check, Finding, LintContext, SourceFile, register

_EXEMPT = {"ref.py", "ops.py", "__init__.py"}


def _twin_candidates(name: str) -> set[str]:
    cands = {f"{name}_ref"}
    if name.endswith("_apply"):
        cands.add(f"{name[: -len('_apply')]}_ref")
    if name.endswith("s"):
        cands.add(f"{name[:-1]}_ref")
    return cands


def _ref_names(src: SourceFile, ctx: LintContext) -> set[str] | None:
    """Top-level def names in the sibling ``ref.py``; None when absent."""
    ref_path = posixpath.join(posixpath.dirname(src.path), "ref.py")
    key = ("rpl005-ref-names", ref_path)
    if key not in ctx.cache:
        try:
            with open(ref_path, encoding="utf-8") as f:
                tree = ast.parse(f.read())
        except (OSError, SyntaxError):
            ctx.cache[key] = None
        else:
            ctx.cache[key] = {
                node.name
                for node in tree.body
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
    return ctx.cache[key]


def _pallas_calls(fn: ast.FunctionDef) -> list[ast.Call]:
    calls = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            func = node.func
            is_pallas = (isinstance(func, ast.Name) and func.id == "pallas_call") or (
                isinstance(func, ast.Attribute) and func.attr == "pallas_call"
            )
            if is_pallas:
                calls.append(node)
    return calls


@register
class KernelTwinContract(Check):
    id = "RPL005"
    title = "pallas_call wrapper missing its ref twin or interpret fallback"
    rationale = (
        "kernel==ref bit-parity and the interpret fallback are what keep "
        "kernels testable off-TPU (DESIGN.md §8); an untwinned kernel is "
        "unverifiable"
    )

    def applies(self, src: SourceFile) -> bool:
        in_kernels = posixpath.basename(posixpath.dirname(src.path)) == "kernels"
        return in_kernels and posixpath.basename(src.path) not in _EXEMPT

    def run(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        wrappers = [
            (node, _pallas_calls(node))
            for node in src.tree.body
            if isinstance(node, ast.FunctionDef)
        ]
        wrappers = [(fn, calls) for fn, calls in wrappers if calls]
        if not wrappers:
            return
        ref_names = _ref_names(src, ctx)
        for fn, calls in wrappers:
            yield from self._check_wrapper(src, fn, calls, ref_names)

    def _check_wrapper(
        self,
        src: SourceFile,
        fn: ast.FunctionDef,
        calls: list[ast.Call],
        ref_names: set[str] | None,
    ) -> Iterator[Finding]:
        args = fn.args
        params = {a.arg for a in args.posonlyargs + args.args + args.kwonlyargs}
        if "interpret" not in params:
            yield self.finding(
                src,
                fn,
                f"kernel wrapper {fn.name}() takes no 'interpret' parameter; "
                "every pallas_call body needs an interpret fallback for "
                "TPU-less environments",
            )
        for call in calls:
            if not any(kw.arg == "interpret" for kw in call.keywords):
                yield self.finding(
                    src,
                    call,
                    f"pallas_call in {fn.name}() does not forward "
                    "interpret=...; the kernel cannot run off-TPU",
                )
        override = src.twin_overrides.get(fn.lineno)
        cands = {override} if override else _twin_candidates(fn.name)
        if ref_names is None:
            yield self.finding(
                src,
                fn,
                f"kernel wrapper {fn.name}() has no kernels/ref.py sibling "
                "to host its reference twin",
            )
        elif not (cands & ref_names):
            yield self.finding(
                src,
                fn,
                f"kernel wrapper {fn.name}() has no reference twin in "
                f"kernels/ref.py (looked for {sorted(cands)}); add the twin "
                "or a '# repro-lint: twin=<name>' marker on the def line",
            )
