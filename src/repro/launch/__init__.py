from repro.launch import mesh, serve, shardings, specs, train
from repro.launch.mesh import make_production_mesh

__all__ = ["mesh", "serve", "shardings", "specs", "train",
           "make_production_mesh"]
