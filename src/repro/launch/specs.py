"""Input ShapeDtypeStructs + shardings for every (arch × input shape) pair.

The four assigned shapes:
    train_4k     seq=4096    global_batch=256   (training step)
    prefill_32k  seq=32768   global_batch=32    (inference prefill)
    decode_32k   seq=32768   global_batch=128   (one-token decode, 32k KV cache)
    long_500k    seq=524288  global_batch=1     (one-token decode, 500k context)

Decode shapes lower ``serve_step`` (one new token + KV cache of seq_len);
long_500k uses each arch's sub-quadratic variant (cfg.long_context_variant()).
Modality frontends are stubs: audio gets frame embeddings, VLM gets image patch
embeddings at d_model (the one sanctioned stub — DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as tf


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def arch_for_shape(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    if shape.name == "long_500k":
        return cfg.long_context_variant()
    return cfg


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function's data arguments."""
    b, t = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    if shape.kind == "train":
        batch: dict[str, Any] = {"labels": sds((b, t), "int32")}
        if cfg.family == "audio":
            batch["frames"] = sds((b, t, cfg.d_model), dt)
        else:
            batch["tokens"] = sds((b, t), "int32")
        if cfg.family == "vlm":
            batch["image_embeds"] = sds((b, cfg.n_image_tokens, cfg.d_model), dt)
        return {"batch": batch}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            toks = sds((b, t, cfg.d_model), dt)
        else:
            toks = sds((b, t), "int32")
        out = {"tokens": toks}
        if cfg.family == "vlm":
            out["image_embeds"] = sds((b, cfg.n_image_tokens, cfg.d_model), dt)
        return out
    # decode: one token + family-specific state of cache_len = seq_len
    state = jax.eval_shape(
        lambda: tf.init_decode_state(cfg, b, t))
    return {"token": sds((b, 1), "int32"), "state": state}


def batch_pspec(rules: dict, ndim: int, seq_dim: int | None = None) -> P:
    spec = [None] * ndim
    spec[0] = rules["batch"]
    if seq_dim is not None and rules.get("seq"):
        spec[seq_dim] = rules["seq"]
    return P(*spec)


def input_pspecs(cfg: ArchConfig, shape: InputShape, rules: dict) -> Any:
    """PartitionSpec tree matching input_specs()."""
    bspec = rules["batch"]
    if shape.kind == "train":
        batch = {"labels": P(bspec, None)}
        if cfg.family == "audio":
            batch["frames"] = P(bspec, rules["seq"], None)
        else:
            batch["tokens"] = P(bspec, None)
        if cfg.family == "vlm":
            batch["image_embeds"] = P(bspec, None, None)
        return {"batch": batch}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            toks = P(bspec, rules["seq"], None)
        else:
            toks = P(bspec, None)
        out = {"tokens": toks}
        if cfg.family == "vlm":
            out["image_embeds"] = P(bspec, None, None)
        return out

    # decode state: shard KV caches along batch + sequence; recurrent states
    # along batch + heads/feature where divisible.
    state_shapes = jax.eval_shape(
        lambda: tf.init_decode_state(cfg, shape.global_batch, shape.seq_len))

    # long_500k passes batch=None / kv_seq=(data..,model) via the rules dict
    # (set in dryrun.run_one), so model constraints and input specs agree.
    kv_seq_axes = rules["kv_seq"]
    eff_bspec = bspec

    def spec_for(path, leaf):
        nd = len(leaf.shape)
        names = [None] * nd
        # batch dim: first dim whose size == global_batch (after stacked dims)
        batch_i = None
        for i, d in enumerate(leaf.shape):
            if d == shape.global_batch:
                names[i] = eff_bspec
                batch_i = i
                break
        if batch_i is None:
            return P(*names)
        # KV caches: [.., B, S, kv, hd] -> shard S over model (+idle batch axes)
        if nd > batch_i + 1 and leaf.shape[batch_i + 1] >= 1024:
            names[batch_i + 1] = kv_seq_axes
        return P(*names)

    state_spec = jax.tree_util.tree_map_with_path(spec_for, state_shapes)
    return {"token": P(eff_bspec, None), "state": state_spec}
