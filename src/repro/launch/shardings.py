"""Parameter/state PartitionSpec assignment by tree-path rules.

Weights get 2D sharding: the contraction-input dim over the 'fsdp' logical axis
(ZeRO-3 style, all-gathered at use) and the parallel dim over 'model' (tensor
parallel). Stacked layer dims (from scan-over-layers) are replicated. The rules
are keyed on leaf names so every architecture family resolves from one table.

Client-parallel round (DESIGN.md §11): stacked per-client state (batches,
residuals, deltas, streams) shards its LEADING axis over the 1-D ``clients``
mesh — ``shard_client_tree`` (re-exported below) is the one way to spell
that placement.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.streams import CLIENT_AXIS, shard_client_tree  # noqa: F401
# (re-exports, not twins: the one spelling of the client placement lives in
# core/streams.py — core must not import launch — and launch-layer callers
# pick it up here)

PyTree = Any

# leaf name -> logical spec for its LAST `len(spec)` dims (leading dims -> None)
_RULES: dict[str, tuple] = {
    # embeddings / heads. The embed table shards its FEATURE dim: the lookup
    # gather and its scatter-add gradient are then device-local (vocab-sharded
    # tables force a replicated multi-GiB embedding gradient).
    "embed": (None, "model"),
    "lm_head": ("fsdp", "vocab"),
    # attention projections (d_in, d_out-parallel)
    "wq": ("fsdp", "model"),
    "wk": ("fsdp", "model"),
    "wv": ("fsdp", "model"),
    "wo": ("model", "fsdp"),
    # dense MLP
    "wi": ("fsdp", "model"),
    "wi_gate": ("fsdp", "model"),
    "wi_up": ("fsdp", "model"),
    # moe (rank-3 expert weights resolved below by rank)
    "router": ("fsdp", None),
    "shared_wi_gate": ("fsdp", "model"),
    "shared_wi_up": ("fsdp", "model"),
    "shared_wo": ("model", "fsdp"),
    # ssm
    "in_proj": ("fsdp", "model"),
    "out_proj": ("model", "fsdp"),
    "conv_w": (None, "model"),
    "A_log": ("heads",),
    "D": ("heads",),
    "dt_bias": ("heads",),
    # xlstm
    "w_in": ("fsdp", "model"),
    "w_qkv": ("fsdp", "model"),
    "w_if": ("fsdp", None),
    "w_o": ("fsdp", "model"),
    "w_out": ("model", "fsdp"),
    "r": (None, "model", None),
    # norms / biases
    "scale": (None,),
    "bias": (None,),
    "b": (None,),
}

_MOE_RANK3 = {
    "wi_gate": ("expert", "fsdp", None),
    "wi_up": ("expert", "fsdp", None),
    "wo": ("expert", None, "fsdp"),
}


def _leaf_logical(path, shape) -> tuple:
    keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    name = next((k for k in reversed(keys) if isinstance(k, str)), None)
    in_moe = "moe" in keys
    base: Optional[tuple] = None
    if in_moe and name in _MOE_RANK3:
        base = _MOE_RANK3[name]
    elif name in _RULES:
        base = _RULES[name]
    if base is None:
        base = (None,) * len(shape)
    if len(base) > len(shape):
        base = base[-len(shape):]
    pad = (None,) * (len(shape) - len(base))
    return pad + tuple(base)


def _resolve(logical: tuple, rules: dict, shape: tuple) -> P:
    phys = []
    for ax, dim in zip(logical, shape):
        if ax is None:
            phys.append(None)
            continue
        target = rules.get(ax)
        if target is None:
            phys.append(None)
            continue
        # require divisibility (GSPMD can pad, but padded params waste memory;
        # fall back to replication when the dim doesn't divide)
        n = 1
        for t in (target if isinstance(target, tuple) else (target,)):
            n *= _AXIS_SIZES.get(t, 1)
        phys.append(target if dim % max(n, 1) == 0 else None)
    return P(*phys)


_AXIS_SIZES: dict[str, int] = {}


def param_specs(params_shape: PyTree, rules: dict, mesh) -> PyTree:
    """PartitionSpec pytree for a params/grads/opt-state tree (by eval_shape)."""
    global _AXIS_SIZES
    _AXIS_SIZES = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _resolve(_leaf_logical(path, leaf.shape), rules,
                                    leaf.shape),
        params_shape,
    )


def named(specs: PyTree, mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
