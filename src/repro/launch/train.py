"""Datacenter train steps: dense FedAvg baseline + THGS/secure-agg federated step.

Two step builders (DESIGN.md §2, §6):

  * ``make_dense_train_step`` — the conventional-FL / data-parallel baseline:
    grads reduce densely over every batch axis (what FedAvg's server sum costs).

  * ``make_fl_train_step`` — the paper's technique as the collective schedule:
    shard_map over the federation axis ('pod' on the multi-pod mesh, 'data'
    otherwise); each participant computes its local update, encodes it with
    the unified stream engine (core/streams.py via core/blocked.py — block-
    local THGS top-k + sparse pairwise masks, DESIGN.md §3), and the
    cross-participant exchange is an all_gather of the small static streams +
    scatter-add — instead of a dense psum. The federation axis is excluded from
    fsdp so every participant owns a full logical model copy.

Training uses plain SGD (the paper's client optimizer); AdamW is available for
the non-FL baseline via ``optimizer=``.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import schedules
from repro.core import streams as se
from repro.core.blocked import decode_blocked_sum, encode_leaf_blocked
from repro.core.types import SecureAggConfig, THGSConfig
from repro.launch import shardings as shd
from repro.models import transformer as tf

PyTree = Any


def _shard_map(f, *, mesh, in_specs, out_specs, manual_axes):
    """Partial-manual shard_map across jax versions.

    jax >= 0.6 exposes jax.shard_map(axis_names=manual set, check_vma=);
    earlier versions have jax.experimental.shard_map(auto=complement set,
    check_rep=). Both mean the same: manual only over ``manual_axes``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False,
                             axis_names=set(manual_axes))
    from jax.experimental.shard_map import shard_map

    auto = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False, auto=auto)


def loss_fn(params: PyTree, cfg: ArchConfig, batch: dict) -> jax.Array:
    return tf.train_loss(params, cfg, batch)


# --------------------------------------------------------------------- dense
def make_dense_train_step(cfg: ArchConfig, lr: float = 0.01,
                          n_micro: int = 1) -> Callable:
    """SGD train step; n_micro > 1 accumulates gradients over microbatches
    (lax.scan over batch splits) — the standard way to fit large models'
    activation footprint on fixed HBM."""

    def step(params: PyTree, batch: dict):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                    *x.shape[1:]), batch)

            def acc_fn(carry, mb):
                loss_a, gacc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, cfg, mb)
                gacc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (loss_a + loss, gacc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros(()), zeros), micro)
            loss = loss / n_micro
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                          ).astype(p.dtype),
            params, grads)
        return new_params, loss

    return step


# ------------------------------------------------------------------ federated
def fl_leaf_plan(params_shape: PyTree, thgs: THGSConfig, n_blocks: int):
    """Static per-leaf (k_block, n_blocks) from the Eq. 1 hierarchical schedule."""
    leaves = jax.tree_util.tree_leaves(params_shape)
    sizes = [leaf.size for leaf in leaves]
    ks = schedules.leaf_ks(thgs, sizes)
    plan = []
    for size, k in zip(sizes, ks):
        from repro.core.blocked import block_layout

        nb, m, _ = block_layout(size, n_blocks)
        plan.append((max(1, -(-k // nb)), nb))
    return plan


def make_fl_train_step_v2(
    cfg: ArchConfig,
    mesh,
    fed_axis: str,
    thgs: THGSConfig,
    sa: SecureAggConfig,
    lr: float = 0.01,
    server_lr: float = 1.0,
    n_micro: int = 1,
) -> Callable:
    """FL step, GSPMD-first variant (the production default).

    shard_map (partial-manual over the federation axis) wraps ONLY the per-
    participant gradient computation — the one thing GSPMD cannot express.
    Everything else (THGS blocked encode, mask generation, the sparse
    exchange, the server update) runs in plain GSPMD on pod-stacked tensors,
    where (a) the partitioner is robust and (b) the sharding-aligned block
    view makes the whole encode zero-communication. The cross-participant
    exchange is the scatter-add of the pod-sharded streams into a pod-
    replicated dense buffer — GSPMD lowers it to an all-gather of exactly the
    sparse streams (the paper's communication claim, visible in the HLO).
    """
    from repro.core.blocked import block_layout, sharding_aligned_transform
    from repro.launch.mesh import logical_rules

    n_fed = dict(zip(mesh.axis_names, mesh.devices.shape))[fed_axis]
    rules = logical_rules(mesh, fed_axis=fed_axis)
    intra_axes = tuple(a for a in mesh.axis_names if a != fed_axis)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def step(params, residuals, batch, round_key):
        params_shape = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        pspecs = jax.tree_util.tree_leaves(
            shd.param_specs(params_shape, rules, mesh),
            is_leaf=lambda x: isinstance(x, P))
        sizes = [x.size for x in jax.tree_util.tree_leaves(params_shape)]
        leaf_k = schedules.leaf_ks(thgs, sizes)

        # ---- per-participant grads (the only manual-region piece) ----
        @functools.partial(
            _shard_map, mesh=mesh,
            in_specs=(P(), P(fed_axis)),
            out_specs=(P(fed_axis), P(fed_axis)),
            manual_axes=(fed_axis,))
        def per_pod_grads(p, b):
            if n_micro == 1:
                loss, grads = jax.value_and_grad(loss_fn)(p, cfg, b)
            else:
                micro = jax.tree_util.tree_map(
                    lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                        *x.shape[1:]), b)

                def acc_fn(carry, mb):
                    l_a, gacc = carry
                    l, gm = jax.value_and_grad(loss_fn)(p, cfg, mb)
                    gacc = jax.tree_util.tree_map(
                        lambda a2, b2: a2 + b2.astype(jnp.float32), gacc, gm)
                    return (l_a + l, gacc), None

                zeros = jax.tree_util.tree_map(
                    lambda q: jnp.zeros(q.shape, jnp.float32), p)
                (loss, grads), _ = jax.lax.scan(
                    acc_fn, (jnp.zeros(()), zeros), micro)
                loss = loss / n_micro
                grads = jax.tree_util.tree_map(lambda g2: g2 / n_micro, grads)
            grads = jax.tree_util.tree_map(
                lambda g2: g2[None].astype(jnp.bfloat16), grads)
            return grads, loss[None]

        grads_stacked, losses = per_pod_grads(params, batch)
        # pin the stacked grads to (fed, param-layout) before the encode —
        # the shard_map exit leaves the intra-participant axes unspecified
        # (observed: replicated-within-pod grads, 2x step memory)
        g_leaves = [
            jax.lax.with_sharding_constraint(
                g2, NamedSharding(mesh, P(fed_axis, *gs)))
            for g2, gs in zip(jax.tree_util.tree_leaves(grads_stacked),
                              pspecs)]
        r_leaves = jax.tree_util.tree_leaves(residuals)
        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        new_params, new_res = [], []
        for leaf_id, (gs, rs, pl, gspec) in enumerate(
                zip(g_leaves, r_leaves, p_leaves, pspecs)):
            shape = pl.shape
            tr = sharding_aligned_transform(shape, gspec, axis_sizes,
                                            intra_axes)
            if os.environ.get("REPRO_FL_V2_GENERIC", "0") == "1":
                tr = None
            if tr is not None:
                to_b, from_b, nb, m, front = tr
            else:
                n_intra = 1
                for a in intra_axes:
                    n_intra *= axis_sizes[a]
                nb, m, padded = block_layout(pl.size, n_intra)
                size0 = pl.size
                to_b = (lambda x, _p=padded, _nb=nb, _m=m, _s=size0:
                        jnp.pad(x.reshape(-1), (0, _p - _s)).reshape(_nb, _m))
                from_b = (lambda b2, _s=size0, _sh=shape:
                          b2.reshape(-1)[:_s].reshape(_sh))
                front = intra_axes if nb == n_intra else ()
            kb = max(1, min(m, -(-leaf_k[leaf_id] // nb)))
            stacked_spec = P(fed_axis, front if front else None, None)

            acc = (jax.vmap(to_b)(rs.astype(jnp.float32))
                   + jax.vmap(to_b)(-lr * gs.astype(jnp.float32)))
            acc = jax.lax.with_sharding_constraint(
                acc, NamedSharding(mesh, stacked_spec))  # [n_fed, nb, m]

            # ---- batched unified-stream encode: all pods in one vmapped
            # program (core/streams.py is the single implementation; pair
            # keys are the fold_in chain both endpoints can derive) ----
            k_mask = (max(1, int(pl.size * sa.mask_ratio / n_fed / nb))
                      if (sa.enabled and n_fed >= 2) else 0)
            if k_mask > 0:
                mkey = jax.random.fold_in(round_key, leaf_id)
                pair_keys, pair_signs = se.fold_pair_key_matrix(mkey, n_fed)
            else:
                pair_keys = pair_signs = None
            streams_b, new_blocks = se.encode_batch_blocks(
                acc, kb, pair_keys=pair_keys, pair_signs=pair_signs,
                k_mask=k_mask, mask_p=sa.p, mask_q=sa.q)
            nr = jax.vmap(from_b)(new_blocks).astype(rs.dtype)
            new_res.append(jax.lax.with_sharding_constraint(
                nr, NamedSharding(mesh, P(fed_axis, *gspec))))

            # ---- the sparse federation exchange: pod-sharded streams ->
            # pod-replicated dense sum (GSPMD: all-gathers only the streams)
            gidx = streams_b.indices              # [n_fed, nb, ktot] global
            dense = jnp.zeros((nb, m), jnp.float32)
            dense = jax.lax.with_sharding_constraint(
                dense, NamedSharding(mesh, P(front if front else None, None)))
            dense = dense.at[gidx // m, gidx % m].add(
                streams_b.values / n_fed)
            agg = from_b(dense).astype(jnp.float32)
            agg = jax.lax.with_sharding_constraint(
                agg, NamedSharding(mesh, gspec))
            new_params.append(
                (pl.astype(jnp.float32) + server_lr * agg).astype(pl.dtype))

        new_params = jax.tree_util.tree_unflatten(treedef, new_params)
        new_res = jax.tree_util.tree_unflatten(treedef, new_res)
        return new_params, new_res, jnp.mean(losses)

    return step



def make_fl_train_step(
    cfg: ArchConfig,
    mesh,
    fed_axis: str,
    thgs: THGSConfig,
    sa: SecureAggConfig,
    lr: float = 0.01,
    server_lr: float = 1.0,
    n_micro: int = 1,
) -> Callable:
    """Returns step(params, residuals, batch, round_key) -> (params, residuals, loss).

    residuals live per-participant: leading dim n_fed, manually sharded over the
    federation axis.
    """
    n_fed = dict(zip(mesh.axis_names, mesh.devices.shape))[fed_axis]
    n_devices = mesh.devices.size
    n_blocks = n_devices // n_fed  # one block per device within a participant

    from repro.launch.mesh import logical_rules

    rules = logical_rules(mesh, fed_axis=fed_axis)
    intra_axes = tuple(a for a in mesh.axis_names if a != fed_axis)

    def step(params, residuals, batch, round_key):
        params_shape = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        plan = fl_leaf_plan(params_shape, thgs, n_blocks)
        grad_specs = jax.tree_util.tree_leaves(
            shd.param_specs(params_shape, rules, mesh),
            is_leaf=lambda x: isinstance(x, P))
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        from repro.core.blocked import sharding_aligned_transform
        # §Perf note: the zero-communication sharding-aligned block view is
        # gated OFF by default — XLA's partial-manual SPMD partitioner cannot
        # form federation peer groups for the transposed view (hard CHECK,
        # tracked upstream as the Shardy migration b/433785288). Enable with
        # REPRO_FL_ALIGNED_BLOCKS=1 once the Shardy partitioner lands.
        use_aligned = os.environ.get("REPRO_FL_ALIGNED_BLOCKS", "0") == "1"
        transforms = [
            (sharding_aligned_transform(leaf.shape, gs, axis_sizes, intra_axes)
             if use_aligned else None)
            for (leaf, gs) in zip(
                jax.tree_util.tree_leaves(params_shape), grad_specs)]
        # per-leaf k_block re-derived for the transform's block count
        from repro.core import schedules as _sched
        sizes = [x.size for x in jax.tree_util.tree_leaves(params_shape)]
        leaf_k = _sched.leaf_ks(thgs, sizes)
        leaf_names = [
            next((str(getattr(q, "key", "")) for q in reversed(path)), "")
            for path, _ in jax.tree_util.tree_flatten_with_path(
                params_shape)[0]]

        @functools.partial(
            _shard_map,
            mesh=mesh,
            in_specs=(P(), P(fed_axis), P(fed_axis), P()),
            out_specs=(P(), P(fed_axis), P(fed_axis)),
            manual_axes=(fed_axis,),
        )
        def fed_step(p, res, b, key):
            # inside: manual over fed_axis; data/model axes still GSPMD-auto.
            # residuals carry an explicit per-participant leading dim (1 here);
            # the batch is just this participant's slice along dim 0.
            res = jax.tree_util.tree_map(lambda x: x[0], res)
            self_id = jax.lax.axis_index(fed_axis)

            if n_micro == 1:
                loss, grads = jax.value_and_grad(loss_fn)(p, cfg, b)
            else:
                micro = jax.tree_util.tree_map(
                    lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                        *x.shape[1:]), b)

                def acc_fn(carry, mb):
                    l_a, gacc = carry
                    l, gm = jax.value_and_grad(loss_fn)(p, cfg, mb)
                    gacc = jax.tree_util.tree_map(
                        lambda a2, b2: a2 + b2.astype(jnp.float32), gacc, gm)
                    return (l_a + l, gacc), None

                zeros = jax.tree_util.tree_map(
                    lambda q: jnp.zeros(q.shape, jnp.float32), p)
                (loss, grads), _ = jax.lax.scan(
                    acc_fn, (jnp.zeros(()), zeros), micro)
                loss = loss / n_micro
                grads = jax.tree_util.tree_map(lambda g2: g2 / n_micro, grads)
            # local update = -lr * grad  (one local FedSGD step)
            leaves, treedef = jax.tree_util.tree_flatten(
                jax.tree_util.tree_map(lambda g: -lr * g, grads))
            res_leaves = jax.tree_util.tree_leaves(res)

            # replicate the small streams within the participant before the
            # cross-participant gather ("gather to leader, then exchange"):
            # XLA's partial-manual partitioner cannot form pod-peer groups
            # for tensors still sharded over the auto axes (hard CHECK).
            replicate = (
                os.environ.get("REPRO_FL_STREAM_REPLICATE", "1") == "1")

            def exchange(stream, nb2, size2, bshard2, tr2):
                # sparse federation exchange for one (sub-)leaf
                g = se.gather_streams(stream, fed_axis, replicate=replicate)
                return decode_blocked_sum(
                    g.indices, g.values, size2, nb2, weight=1.0 / n_fed,
                    block_sharding=bshard2, transform=tr2)

            new_res, agg_leaves = [], []
            for leaf_id, (g, r, (kb, nb)) in enumerate(
                    zip(leaves, res_leaves, plan)):
                tr = transforms[leaf_id]
                if tr is not None:
                    nb = tr[2]
                    kb = max(1, -(-leaf_k[leaf_id] // nb))
                # normalize the embedding grad's sharding to the param layout
                # first — the scatter-produced cotangent otherwise reaches the
                # blocked encode with a layout the partial-manual partitioner
                # cannot form federation peer groups for (hard XLA CHECK).
                # Constraining every leaf trips the same CHECK on small meshes,
                # so only the scatter-produced leaf is normalized.
                if leaf_names[leaf_id] == "embed":
                    g = jax.lax.with_sharding_constraint(
                        g, grad_specs[leaf_id])
                k_mask_block = 0
                mask_key = None
                if sa.enabled and n_fed >= 2:
                    k_mask_block = max(
                        1, int(g.size * sa.mask_ratio / n_fed / nb))
                    mask_key = jax.random.fold_in(key, leaf_id)
                try:  # blocks align with this leaf's sharded axes
                    am = jax.sharding.get_abstract_mesh()
                    axes = tr[4] if tr is not None else intra_axes
                    bshard = NamedSharding(am, P(axes, None))
                except Exception:
                    bshard = None

                # Large stacked leaves: scan the encode+exchange over the
                # leading (layer) dim — the pad/reshape to the block view
                # replicates ONE slice, not the whole multi-GiB leaf
                # (measured: granite-20b FL train 172 GiB -> per-layer-slice).
                # flatten the stacked UNSHARDED leading dims into the scan
                # axis (merging a sharded dim into the scan axis would force
                # GSPMD to replicate the whole leaf — observed 150 GiB on the
                # llama4 expert tensors); chunk huge 2D leaves the same way
                spec_entries = tuple(grad_specs[leaf_id]) + (None,) * g.ndim
                if g.ndim >= 3:
                    lead = 1
                    n_lead_dims = 0
                    for di, d in enumerate(g.shape[:-2]):
                        if spec_entries[di] is not None:
                            break
                        lead *= d
                        n_lead_dims += 1
                    slice_shape = g.shape[n_lead_dims:]
                elif g.ndim == 2 and g.size >= 1 << 28 and g.shape[0] % 16 == 0 \
                        and spec_entries[0] is None:
                    lead, slice_shape = 16, (g.shape[0] // 16, g.shape[1])
                else:
                    lead, slice_shape = 0, None
                if (tr is None and lead > 1
                        and g.size // lead >= 1 << 20):
                    g = g.reshape(lead, *slice_shape)
                    r = r.reshape(lead, *slice_shape)
                    kb_s = max(1, -(-leaf_k[leaf_id] // (nb * lead)))
                    km_s = (max(1, k_mask_block // lead)
                            if k_mask_block else 0)

                    def slice_body(i, gr, _kb=kb_s, _km=km_s, _nb=nb,
                                   _lid=leaf_id, _bs=bshard):
                        gi, ri = gr
                        mk = (jax.random.fold_in(
                            jax.random.fold_in(key, _lid), i)
                            if _km else None)
                        st, rn = encode_leaf_blocked(
                            gi, ri, _kb, _nb,
                            mask_key=mk, k_mask_block=_km,
                            n_peers=n_fed, self_id=self_id,
                            mask_lo=sa.p, mask_q=sa.q, block_sharding=_bs)
                        dense = exchange(st, _nb, gi.size, _bs, None)
                        return dense.reshape(gi.shape), rn

                    def scan_fn(i, gr):
                        out = slice_body(i, gr)
                        return i + 1, out

                    _, (agg_sl, res_sl) = jax.lax.scan(
                        scan_fn, jnp.int32(0), (g, r))
                    orig_shape = leaves[leaf_id].shape
                    new_res.append(
                        res_sl.reshape(orig_shape).astype(r.dtype))
                    agg_leaves.append(
                        agg_sl.reshape(orig_shape).astype(g.dtype))
                    continue

                stream, r_new = encode_leaf_blocked(
                    g, r, kb, nb,
                    mask_key=mask_key, k_mask_block=k_mask_block,
                    n_peers=n_fed, self_id=self_id,
                    mask_lo=sa.p, mask_q=sa.q, block_sharding=bshard,
                    transform=tr)
                new_res.append(r_new)
                # ---- the sparse federation exchange (vs dense psum) ----
                dense = exchange(stream, nb, g.size, bshard, tr)
                agg = (dense if tr is not None
                       else dense.reshape(g.shape)).astype(g.dtype)
                if tr is None:
                    try:  # back to the param layout for the update
                        agg = jax.lax.with_sharding_constraint(
                            agg, NamedSharding(
                                jax.sharding.get_abstract_mesh(),
                                grad_specs[leaf_id]))
                    except Exception:
                        pass
                agg_leaves.append(agg)

            agg = jax.tree_util.tree_unflatten(treedef, agg_leaves)
            new_p = jax.tree_util.tree_map(
                lambda pi, d: (pi.astype(jnp.float32) +
                               server_lr * d.astype(jnp.float32)
                               ).astype(pi.dtype), p, agg)
            new_res = jax.tree_util.tree_unflatten(treedef, new_res)
            # restore leading fed dim for the per-participant state
            new_res = jax.tree_util.tree_map(lambda x: x[None], new_res)
            return new_p, new_res, loss[None]

        new_params, new_res, losses = fed_step(params, residuals, batch,
                                               round_key)
        return new_params, new_res, jnp.mean(losses)

    return step


def init_fl_residuals(params_shape: PyTree, n_fed: int) -> PyTree:
    """ShapeDtypeStructs for the per-participant residual state (bf16)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct((n_fed,) + x.shape, jnp.bfloat16),
        params_shape)
