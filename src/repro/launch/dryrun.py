import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh) combo.

For each combination this:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. resolves parameter/state/input shardings,
  3. ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` — no allocation,
  4. records memory_analysis(), cost_analysis(), and the collective-byte
     breakdown parsed from the compiled HLO,
  5. writes experiments/dryrun/<arch>__<shape>__<mesh>[__fl].json.

Any failure here (sharding mismatch, OOM at compile, unsupported collective)
is a bug in the system. benchmarks/roofline.py consumes the JSON artifacts.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh pod --fl
"""
import argparse
import json
import re
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core.types import SecureAggConfig, THGSConfig
from repro.launch import serve, shardings as shd, train
from repro.launch.mesh import logical_rules, make_production_mesh
from repro.launch.specs import SHAPES, arch_for_shape, input_pspecs, input_specs
from repro.models import transformer as tf
from repro.models.sharding import logical_axis_rules

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64)\[([\d,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def parse_collectives(hlo: str) -> dict:
    """Sum result-shape bytes of every collective op in the compiled HLO."""
    out: dict[str, dict] = {k: {"bytes": 0, "count": 0} for k in COLLECTIVE_OPS}
    for line in hlo.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+)", s)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b([a-z0-9\-]+)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        base = next((c for c in COLLECTIVE_OPS if op == c or
                     op.startswith(c + "-")), None)
        if base is None:
            continue
        # result shapes appear before the op name; take everything up to ' = '
        result_part = rhs.split(opm.group(1) + "(")[0]
        nbytes = 0
        for dm in _SHAPE_RE.finditer(result_part):
            dtype, dims = dm.group(1), dm.group(2)
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _BYTES[dtype]
        out[base]["bytes"] += nbytes
        out[base]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if "argument_size_in_bytes" in out:
        out["per_device_total_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0))
    return out


def cost_summary(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "transcendentals")
                or k.startswith("bytes accessed"))}


def build_step(cfg, shape, mesh, rules, fl: bool, thgs=None, sa=None):
    """Returns (fn, example kwargs of ShapeDtypeStructs, in_shardings tree)."""
    cfg = arch_for_shape(cfg, shape)
    pshapes = jax.eval_shape(lambda k: tf.init_params(cfg, k),
                             jax.random.key(0))
    pspecs = shd.param_specs(pshapes, rules, mesh)
    pshard = shd.named(pspecs, mesh)
    ins = input_specs(cfg, shape)
    ispecs = input_pspecs(cfg, shape, rules)
    ishard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), ispecs,
        is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        if fl:
            fed_axis = "pod" if "pod" in mesh.axis_names else "data"
            n_fed = dict(zip(mesh.axis_names, mesh.devices.shape))[fed_axis]
            thgs = thgs or THGSConfig(s0=0.01, alpha=0.9, s_min=0.001)
            sa = sa or SecureAggConfig(mask_ratio=0.01)
            n_params = sum(x.size for x in jax.tree_util.tree_leaves(pshapes))
            n_micro = 8 if n_params > 50e9 else (4 if n_params > 12e9 else
                                                 (2 if n_params > 4e9 else 1))
            step = train.make_fl_train_step(cfg, mesh, fed_axis, thgs, sa,
                                            n_micro=n_micro)
            res = train.init_fl_residuals(pshapes, n_fed)
            # residuals: per-participant over the federation axis AND
            # param-layout sharded within the participant
            res_shard = jax.tree_util.tree_map(
                lambda ps: NamedSharding(mesh, P(fed_axis, *ps)),
                pspecs, is_leaf=lambda x: isinstance(x, P))
            args = dict(params=pshapes, residuals=res,
                        batch=ins["batch"],
                        round_key=jax.eval_shape(lambda: jax.random.key(0)))
            shards = dict(params=pshard, residuals=res_shard,
                          batch=ishard["batch"],
                          round_key=NamedSharding(mesh, P()))
            fn = lambda params, residuals, batch, round_key: step(
                params, residuals, batch, round_key)
            return fn, args, shards
        # microbatch count scales with model size (activation footprint)
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(pshapes))
        n_micro = 8 if n_params > 50e9 else (4 if n_params > 12e9 else
                                             (2 if n_params > 4e9 else 1))
        step = train.make_dense_train_step(cfg, n_micro=n_micro)
        args = dict(params=pshapes, batch=ins["batch"])
        shards = dict(params=pshard, batch=ishard["batch"])
        return (lambda params, batch: step(params, batch)), args, shards

    if shape.kind == "prefill":
        step = serve.make_prefill_step(cfg, cache_len=shape.seq_len)
        args = dict(params=pshapes, tokens=ins["tokens"])
        shards = dict(params=pshard, tokens=ishard["tokens"])
        if cfg.family == "vlm":
            args["image_embeds"] = ins["image_embeds"]
            shards["image_embeds"] = ishard["image_embeds"]
        return (lambda params, tokens, image_embeds=None: step(
            params, tokens, image_embeds)), args, shards

    step = serve.make_decode_step(cfg)
    args = dict(params=pshapes, token=ins["token"], state=ins["state"])
    shards = dict(params=pshard, token=ishard["token"],
                  state=ishard["state"])
    return (lambda params, token, state: step(params, token, state)), args, shards


def run_one(arch: str, shape_name: str, mesh_kind: str, fl: bool = False,
            out_dir: str = "experiments/dryrun", kv_int8: bool = False) -> dict:
    cfg = configs.get(arch)
    if kv_int8:
        import dataclasses as _dc

        cfg = _dc.replace(cfg, kv_dtype="int8")
    shape = SHAPES[shape_name]
    if not cfg.supports_shape(shape_name):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped", "reason": "encoder-only: no decode step"}
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
        with open(path + ".tmp", "w") as f:
            json.dump(rec, f, indent=1)
        os.replace(path + ".tmp", path)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod"))
    fed_axis = ("pod" if "pod" in mesh.axis_names else "data") if fl else None
    rules = logical_rules(mesh, fed_axis=fed_axis)
    if shape.global_batch == 1:
        # long_500k: batch carries no parallelism -> fold the idle batch axes
        # into the KV-cache sequence sharding (model code + input specs agree)
        batch_axes = rules["batch"] if isinstance(rules["batch"], tuple) \
            else (rules["batch"],)
        rules = {**rules, "kv_seq": tuple(a for a in batch_axes if a) + ("model",),
                 "batch": None}
    t0 = time.perf_counter()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "fl": fl,
           "kv_int8": kv_int8, "n_devices": int(mesh.devices.size)}
    try:
        with logical_axis_rules(mesh, rules):
            fn, args, shards = build_step(
                cfg, shape, mesh, rules, fl)
            # donate mutable state, as the real launcher does: decode donates
            # its KV/recurrent caches; training donates params (+ residuals)
            if shape.kind == "decode":
                donate = (2,)
            elif shape.kind == "train":
                donate = (0, 1) if fl else (0,)
            else:
                donate = ()
            jitted = jax.jit(fn, in_shardings=tuple(
                shards[k] for k in args), donate_argnums=donate)
            lowered = jitted.lower(*[args[k] for k in args])
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=memory_summary(compiled),
            cost=cost_summary(compiled),
            collectives=parse_collectives(compiled.as_text()),
        )
    except Exception as e:
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    os.makedirs(out_dir, exist_ok=True)
    tag = (f"{arch}__{shape_name}__{mesh_kind}" + ("__fl" if fl else "")
           + ("__kvint8" if kv_int8 else ""))
    path = os.path.join(out_dir, tag + ".json")
    with open(path + ".tmp", "w") as f:
        json.dump(rec, f, indent=1, default=str)
    os.replace(path + ".tmp", path)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "pod", "both"])
    ap.add_argument("--fl", action="store_true",
                    help="lower the THGS+secure-agg federated train step")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache variant (beyond-paper decode memory)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = configs.all_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "pod"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_one(arch, shape, mk, fl=args.fl, out_dir=args.out,
                              kv_int8=args.kv_int8)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    mem = rec["memory"].get("per_device_total_bytes")
                    col = rec["collectives"]["total_bytes"]
                    extra = (f" mem/dev={mem/2**30:.2f}GiB "
                             f"coll={col/2**30:.2f}GiB "
                             f"compile={rec['compile_s']:.0f}s")
                elif status == "fail":
                    n_fail += 1
                    extra = " " + rec["error"][:160]
                print(f"[{status:7s}] {arch:24s} {shape:12s} {mk:6s}"
                      f"{' fl' if args.fl else '':3s}{extra}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
