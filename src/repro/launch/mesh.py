"""Production mesh + logical-axis rules.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is the
federation axis — each pod is one cross-silo FL participant (DESIGN.md §2).

The third mesh family is the 1-D **clients** mesh (DESIGN.md §11): the
simulation/reference round partitions a cohort of simulated clients over
whatever devices are local — `make_clients_mesh` / `clients_mesh_for` — so
`core/fedavg.run_round` can run its local-SGD + THGS encode + pair-mask PRNG
per-shard under shard_map. Testable on CPU via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Defined as functions so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS before first jax init; tests see 1 device).
"""
from __future__ import annotations

import jax

from repro.core.streams import CLIENT_AXIS


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, multi_pod: bool = False):
    """Small mesh for subprocess sharding tests (host platform device count)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_clients_mesh(n_devices: int | None = None):
    """1-D ``clients`` mesh over the first ``n_devices`` local devices
    (default: all of them). The client-parallel round's only mesh shape."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if not 1 <= n <= len(devs):
        raise ValueError(f"n_devices={n} outside [1, {len(devs)}]")
    from jax.sharding import Mesh
    import numpy as np

    return Mesh(np.asarray(devs[:n]), (CLIENT_AXIS,))


def clients_mesh_for(cohort_size: int):
    """The largest usable clients mesh for this cohort, or None.

    shard_map needs equal shards, so the mesh size must divide the cohort;
    pick the largest divisor of ``cohort_size`` that fits the local device
    count. Returns None when that divisor is 1 (single device or indivisible
    cohort) — callers then stay on the vmap fallback path.
    """
    n_dev = len(jax.devices())
    best = max((d for d in range(1, min(n_dev, cohort_size) + 1)
                if cohort_size % d == 0), default=1)
    if best <= 1:
        return None
    return make_clients_mesh(best)


def default_tree_groups(cohort_size: int) -> int:
    """Auto group count for the hierarchical aggregation tree (DESIGN.md
    §13): ~sqrt(cohort) sub-aggregators balances per-group ingress
    (O(n·k/G) stream slots) against the root combine (G partials), the
    classic two-level fan-in. Always >= 2 so 'tree' actually builds a tree.
    Must match the inline fallback in core/fedavg.run_round (core cannot
    import launch)."""
    return max(2, int(round(cohort_size ** 0.5)))


def logical_rules(mesh, *, fsdp: bool = True, fed_axis: str | None = None) -> dict:
    """Map the model code's logical axis names onto this mesh's physical axes.

    fed_axis: the federation axis for FL training — params must NOT be
    fsdp-sharded along it (each participant owns a full model view along the
    federation axis), so it is excluded from 'fsdp'.
    """
    axes = mesh.axis_names
    has_pod = "pod" in axes
    # inside the FL shard_map the federation axis is manual — model-code
    # sharding constraints must not mention it
    batch_axes = tuple(a for a in axes if a in ("pod", "data") and a != fed_axis)
    fsdp_axis = "data" if (fsdp and "data" in axes and fed_axis != "data") else None
    return {
        "batch": batch_axes if len(batch_axes) > 1 else batch_axes[0],
        "seq": "model",      # sequence-parallel residual stream
        "model": "model",    # tensor-parallel feature dim
        "heads": "model",
        "expert": "model",
        "vocab": "model",
        "fsdp": fsdp_axis,
        "kv_seq": "model",   # decode KV cache sharded along sequence
        "pod": "pod" if has_pod else None,
    }


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
