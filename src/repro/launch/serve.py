"""Serving steps: prefill (prompt -> logits + caches) and one-token decode.

Decode donates the state buffers so the KV cache updates in place.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.configs.base import ArchConfig
from repro.models import transformer as tf


def make_prefill_step(cfg: ArchConfig, cache_len: int) -> Callable:
    def step(params, tokens, image_embeds=None):
        return tf.prefill(params, cfg, tokens, cache_len,
                          image_embeds=image_embeds)

    return step


def make_decode_step(cfg: ArchConfig) -> Callable:
    def step(params, token, state):
        return tf.decode_step(params, cfg, token, state)

    return step


def next_token(logits) -> jax.Array:
    """Greedy int32[B, 1] token from logits of any serving shape.

    Prefill emits ``[B, T, V]`` (the last position is the prediction);
    decode emits ``[B, 1, V]`` or ``[B, V]`` depending on the family. The
    ``ndim`` test is static at trace time, so this is jit-safe.
    """
    if logits.ndim == 3:
        logits = logits[:, -1, :]
    return jax.numpy.argmax(logits, -1)[:, None].astype(jax.numpy.int32)


def greedy_generate(params, cfg: ArchConfig, prompt, n_new: int,
                    cache_len: int):
    """Host-driven greedy loop (examples / integration tests)."""
    logits, state = jax.jit(make_prefill_step(cfg, cache_len))(params, prompt)
    step = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
    tok = next_token(logits)
    out = [tok]
    for _ in range(n_new - 1):
        logits, state = step(params, tok, state)
        tok = next_token(logits)
        out.append(tok)
    return jax.numpy.concatenate(out, axis=1)
