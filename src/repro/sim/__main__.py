"""CLI for the simulation engine.

    python -m repro.sim --preset table2_quick
    python -m repro.sim --list
    python -m repro.sim --preset quickstart --rounds 6 --out /tmp/run.json

Runs the named preset (with any overrides), prints per-eval progress and the
final ledger summary under both bit accountings, and writes the JSON ledger to
``--out`` (or the preset's default path).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

from repro.core.codecs import CODECS
from repro.sim import presets
from repro.sim.engine import AsyncSimulation, Simulation
from repro.sim.ledger import mib


def _progress_hook(round_t: int, info: dict) -> None:
    if "acc" in info:
        rec = info["record"]
        drop = f" dropped={list(info['dropped'])}" if info["dropped"] else ""
        print(f"round {round_t + 1:4d}  acc={info['acc']:.3f}  "
              f"loss={info['loss']:.4f}  "
              f"upload={mib(rec.upload_bits):.2f} MiB "
              f"({rec.compression:.1f}x vs dense){drop}", flush=True)


def _sweep_overrides(args, cfg):
    """CLI overrides that apply to every arm of a sweep (no --codec: the
    sweep itself owns the codec axis)."""
    over = {}
    if args.rounds is not None:
        over["rounds"] = args.rounds
    if args.seed is not None:
        over["seed"] = args.seed
    if args.dropout is not None:
        over["dropout_rate"] = args.dropout
    if args.shard_clients is not None:
        over["shard_clients"] = args.shard_clients
    if args.quick:
        over.setdefault("rounds", min(3, cfg.rounds))
        over.setdefault("n_train", min(600, cfg.n_train))
        over.setdefault("n_test", min(200, cfg.n_test))
        over["eval_every"] = 1
    return over


def _run_dp_sweep(args) -> int:
    """Run every noise-multiplier arm of a DP frontier sweep and write one
    combined JSON recording the privacy/accuracy/communication trade-off
    (arms share protocol and seed; only the DP noise differs — the z=0 arm
    is a plain secagg run)."""
    arms = presets.dp_sweep_configs(args.preset)
    runs: dict[str, dict] = {}
    for label, cfg in arms.items():
        cfg = cfg.replace(**_sweep_overrides(args, cfg))
        print(f"# sweep={args.preset} arm dp={label} rounds={cfg.rounds} "
              f"cohort={cfg.clients_per_round}/{cfg.n_clients}", flush=True)
        res = Simulation(cfg).run(resume=False, hooks=[_progress_hook])
        runs[label] = res.summary()
    print(f"\n# {args.preset}: privacy/accuracy/communication frontier")
    for label, summ in runs.items():
        t = summ["ledger"]["paper"]
        priv = summ["ledger"].get("privacy")
        eps = (f"eps={priv['epsilon']:8.3f} at delta={priv['delta']:g}"
               if priv else "eps=   inf (no noise)  ")
        print(f"{label:6s} {eps}  acc={summ['final_acc']:.3f}  "
              f"upload={t['upload_mib']:.2f} MiB "
              f"({t['upload_vs_dense']:.1%} of dense)")
    out = args.out or f"experiments/sim/{args.preset}.json"
    d = os.path.dirname(out)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = out + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"name": args.preset, "runs": runs}, f, indent=2,
                  default=float)
    os.replace(tmp, out)
    print(f"sweep ledger written to {out}")
    return 0


def _run_sweep(args) -> int:
    """Run every codec arm of a sweep preset and write one combined JSON.

    Arms share the Table 2 protocol and seed; only the wire codec differs
    (secure aggregation is off in every arm — presets.sweep_configs). The
    combined JSON maps codec -> full run summary so CI and EXPERIMENTS.md can
    compare ledger upload bits like-for-like.
    """
    if args.codec is not None:
        print("error: --codec conflicts with a sweep preset "
              "(the sweep runs every codec)", file=sys.stderr)
        return 2
    arms = presets.sweep_configs(args.preset)
    runs: dict[str, dict] = {}
    for codec, cfg in arms.items():
        cfg = cfg.replace(**_sweep_overrides(args, cfg))
        print(f"# sweep={args.preset} arm codec={codec} rounds={cfg.rounds} "
              f"cohort={cfg.clients_per_round}/{cfg.n_clients}", flush=True)
        res = Simulation(cfg).run(resume=False, hooks=[_progress_hook])
        runs[codec] = res.summary()
    print(f"\n# {args.preset}: upload vs f32 baseline")
    for acct in ("paper", "tpu"):
        base = runs["f32"]["ledger"][acct]["upload_bits"] if "f32" in runs \
            else None
        for codec, summ in runs.items():
            t = summ["ledger"][acct]
            rel = (f"  ({t['upload_bits'] / base:6.1%} of f32)"
                   if base else "")
            print(f"[{acct:5s}] {codec:5s} upload {t['upload_mib']:9.2f} MiB "
                  f"acc={summ['final_acc']:.3f}{rel}")
    out = args.out or f"experiments/sim/{args.preset}.json"
    d = os.path.dirname(out)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = out + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"name": args.preset, "runs": runs}, f, indent=2,
                  default=float)
    os.replace(tmp, out)
    print(f"sweep ledger written to {out}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sim",
        description="Run a named federated-simulation preset.")
    ap.add_argument("--preset", default=None,
                    help=f"one of: {', '.join(presets.names())}")
    ap.add_argument("--list", action="store_true",
                    help="list presets and exit")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--dropout", type=float, default=None,
                    help="override dropout_rate")
    ap.add_argument("--ckpt-dir", default=None,
                    help="enable checkpoint/resume in this directory")
    ap.add_argument("--ckpt-every", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="JSON ledger path (default: the preset's out_json)")
    ap.add_argument("--quick", action="store_true",
                    help="shrink the run for CI smoke (3 rounds, small data)")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore existing checkpoints")
    ap.add_argument("--shard-clients", choices=("auto", "on", "off"),
                    default=None,
                    help="client-parallel rounds over local devices "
                         "(DESIGN.md §11); default: the preset's setting")
    ap.add_argument("--codec", choices=CODECS, default=None,
                    help="stream wire codec (DESIGN.md §12); a non-f32 codec "
                         "on a secagg preset disables secure aggregation "
                         "loudly (masks cancel only on the f32 grid)")
    ap.add_argument("--topology", choices=("flat", "tree"), default=None,
                    help="aggregation topology (DESIGN.md §13); 'tree' is "
                         "bit-exact with 'flat'")
    ap.add_argument("--tree-groups", type=int, default=None,
                    help="sub-aggregator count for --topology tree "
                         "(0 = auto, ~sqrt cohort)")
    ap.add_argument("--dp-sigma", type=float, default=None,
                    help="distributed-DP cohort-sum noise multiplier z "
                         "(core/dp.py, DESIGN.md §15); 0 disables noise")
    ap.add_argument("--dp-clip", type=float, default=None,
                    help="per-client L2 clip S for distributed DP")
    ap.add_argument("--dp-delta", type=float, default=None,
                    help="DP accountant target delta (default 1e-5)")
    args = ap.parse_args(argv)

    if args.list or not args.preset:
        for name in presets.names():
            cfg = presets.get(name)
            mech = ("thgs+sa" if cfg.thgs and cfg.sa.enabled
                    else "thgs" if cfg.thgs else "dense")
            print(f"{name:22s} {cfg.model}/{cfg.dataset} "
                  f"{cfg.partition:9s} rounds={cfg.rounds:<3d} "
                  f"cohort={cfg.clients_per_round}/{cfg.n_clients} {mech}")
        for name, arm_codecs in sorted(presets.SWEEPS.items()):
            print(f"{name:22s} sweep over codecs: {', '.join(arm_codecs)}")
        for name, sigmas in sorted(presets.DP_SWEEPS.items()):
            print(f"{name:22s} sweep over dp noise z: "
                  f"{', '.join(f'{z:g}' for z in sigmas)}")
        return 0 if args.list else 2

    if args.preset in presets.SWEEPS:
        return _run_sweep(args)
    if args.preset in presets.DP_SWEEPS:
        return _run_dp_sweep(args)

    try:
        cfg = presets.get(args.preset)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    over = {}
    if args.rounds is not None:
        over["rounds"] = args.rounds
    if args.seed is not None:
        over["seed"] = args.seed
    if args.dropout is not None:
        over["dropout_rate"] = args.dropout
    if args.ckpt_dir is not None:
        over["ckpt_dir"] = args.ckpt_dir
    if args.ckpt_every is not None:
        over["ckpt_every"] = args.ckpt_every
    if args.out is not None:
        over["out_json"] = args.out
    if args.shard_clients is not None:
        over["shard_clients"] = args.shard_clients
    if args.topology is not None:
        over["topology"] = args.topology
    if args.tree_groups is not None:
        over["tree_groups"] = args.tree_groups
    if (args.dp_sigma is not None or args.dp_clip is not None
            or args.dp_delta is not None):
        from repro.core.dp import DPConfig

        dp = cfg.dp or DPConfig()
        dp_over = {}
        if args.dp_sigma is not None:
            dp_over["sigma"] = args.dp_sigma
        if args.dp_clip is not None:
            dp_over["clip"] = args.dp_clip
        if args.dp_delta is not None:
            dp_over["delta"] = args.dp_delta
        over["dp"] = dataclasses.replace(dp, **dp_over)
    if args.codec is not None:
        over["codec"] = args.codec
        if args.codec != "f32" and cfg.sa.enabled:
            print(f"# NOTE: codec={args.codec} disables secure aggregation "
                  "for this run — sparse pair masks cancel bit-exactly only "
                  "on the f32 grid (DESIGN.md §12)", flush=True)
            over["sa"] = dataclasses.replace(cfg.sa, enabled=False)
    if args.quick:
        over.setdefault("rounds", min(3, cfg.rounds))
        over.setdefault("n_train", min(600, cfg.n_train))
        over.setdefault("n_test", min(200, cfg.n_test))
        over["eval_every"] = 1
    cfg = cfg.replace(**over)

    sim = (AsyncSimulation if cfg.mode == "async" else Simulation)(cfg)
    mesh_note = (f" clients_mesh={sim.mesh.devices.size}dev"
                 if sim.mesh is not None else "")
    mode_note = (f" mode=async buffer={sim.buffer} "
                 f"max_staleness={cfg.max_staleness}"
                 if cfg.mode == "async" else "")
    topo_note = (f" topology=tree groups={cfg.tree_groups or 'auto'}"
                 if cfg.topology == "tree" else "")
    dp_note = (f" dp=clip{cfg.dp.clip:g}/z{cfg.dp.sigma:g}"
               if cfg.dp is not None and cfg.dp.active else "")
    print(f"# preset={args.preset} model={cfg.model} dataset={cfg.dataset} "
          f"partition={cfg.partition} rounds={cfg.rounds} "
          f"cohort={cfg.clients_per_round}/{cfg.n_clients}"
          f"{mesh_note}{mode_note}{topo_note}{dp_note}",
          flush=True)
    res = sim.run(resume=not args.no_resume, hooks=[_progress_hook])

    for acct in ("paper", "tpu"):
        t = res.ledger.totals(acct)
        print(f"[{acct:5s}] upload {t['upload_mib']:9.2f} MiB vs dense "
              f"{t['dense_upload_mib']:9.2f} MiB -> "
              f"{t['upload_vs_dense']:6.1%} of FedAvg "
              f"({t['compression_x']:.1f}x)")
        if t["share_upload_bits"] or t["recovery_upload_bits"]:
            print(f"[{acct:5s}] secagg control: shares "
                  f"{mib(t['share_upload_bits']):.4f} MiB + recovery "
                  f"{mib(t['recovery_upload_bits']):.4f} MiB -> total "
                  f"{t['total_upload_vs_dense']:6.1%} of FedAvg")
    priv = res.ledger.privacy()
    if priv is not None:
        print(f"[dp   ] eps={priv['epsilon']:.3f} at delta={priv['delta']:g} "
              f"over {priv['rounds']} noised round(s) "
              f"(clip={priv['clip']:g}, z={priv['noise_multiplier']:g})")
    print(f"final_acc={res.final_acc:.3f}  wall={res.wall_s:.1f}s")
    if cfg.out_json:
        path = res.to_json(cfg.out_json)
        print(f"ledger written to {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
