"""CLI for the simulation engine.

    python -m repro.sim --preset table2_quick
    python -m repro.sim --list
    python -m repro.sim --preset quickstart --rounds 6 --out /tmp/run.json

Runs the named preset (with any overrides), prints per-eval progress and the
final ledger summary under both bit accountings, and writes the JSON ledger to
``--out`` (or the preset's default path).
"""
from __future__ import annotations

import argparse
import sys

from repro.sim import presets
from repro.sim.engine import Simulation
from repro.sim.ledger import mib


def _progress_hook(round_t: int, info: dict) -> None:
    if "acc" in info:
        rec = info["record"]
        drop = f" dropped={list(info['dropped'])}" if info["dropped"] else ""
        print(f"round {round_t + 1:4d}  acc={info['acc']:.3f}  "
              f"loss={info['loss']:.4f}  "
              f"upload={mib(rec.upload_bits):.2f} MiB "
              f"({rec.compression:.1f}x vs dense){drop}", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sim",
        description="Run a named federated-simulation preset.")
    ap.add_argument("--preset", default=None,
                    help=f"one of: {', '.join(presets.names())}")
    ap.add_argument("--list", action="store_true",
                    help="list presets and exit")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--dropout", type=float, default=None,
                    help="override dropout_rate")
    ap.add_argument("--ckpt-dir", default=None,
                    help="enable checkpoint/resume in this directory")
    ap.add_argument("--ckpt-every", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="JSON ledger path (default: the preset's out_json)")
    ap.add_argument("--quick", action="store_true",
                    help="shrink the run for CI smoke (3 rounds, small data)")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore existing checkpoints")
    ap.add_argument("--shard-clients", choices=("auto", "on", "off"),
                    default=None,
                    help="client-parallel rounds over local devices "
                         "(DESIGN.md §11); default: the preset's setting")
    args = ap.parse_args(argv)

    if args.list or not args.preset:
        for name in presets.names():
            cfg = presets.get(name)
            mech = ("thgs+sa" if cfg.thgs and cfg.sa.enabled
                    else "thgs" if cfg.thgs else "dense")
            print(f"{name:22s} {cfg.model}/{cfg.dataset} "
                  f"{cfg.partition:9s} rounds={cfg.rounds:<3d} "
                  f"cohort={cfg.clients_per_round}/{cfg.n_clients} {mech}")
        return 0 if args.list else 2

    try:
        cfg = presets.get(args.preset)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    over = {}
    if args.rounds is not None:
        over["rounds"] = args.rounds
    if args.seed is not None:
        over["seed"] = args.seed
    if args.dropout is not None:
        over["dropout_rate"] = args.dropout
    if args.ckpt_dir is not None:
        over["ckpt_dir"] = args.ckpt_dir
    if args.ckpt_every is not None:
        over["ckpt_every"] = args.ckpt_every
    if args.out is not None:
        over["out_json"] = args.out
    if args.shard_clients is not None:
        over["shard_clients"] = args.shard_clients
    if args.quick:
        over.setdefault("rounds", min(3, cfg.rounds))
        over.setdefault("n_train", min(600, cfg.n_train))
        over.setdefault("n_test", min(200, cfg.n_test))
        over["eval_every"] = 1
    cfg = cfg.replace(**over)

    sim = Simulation(cfg)
    mesh_note = (f" clients_mesh={sim.mesh.devices.size}dev"
                 if sim.mesh is not None else "")
    print(f"# preset={args.preset} model={cfg.model} dataset={cfg.dataset} "
          f"partition={cfg.partition} rounds={cfg.rounds} "
          f"cohort={cfg.clients_per_round}/{cfg.n_clients}{mesh_note}",
          flush=True)
    res = sim.run(resume=not args.no_resume, hooks=[_progress_hook])

    for acct in ("paper", "tpu"):
        t = res.ledger.totals(acct)
        print(f"[{acct:5s}] upload {t['upload_mib']:9.2f} MiB vs dense "
              f"{t['dense_upload_mib']:9.2f} MiB -> "
              f"{t['upload_vs_dense']:6.1%} of FedAvg "
              f"({t['compression_x']:.1f}x)")
        if t["share_upload_bits"] or t["recovery_upload_bits"]:
            print(f"[{acct:5s}] secagg control: shares "
                  f"{mib(t['share_upload_bits']):.4f} MiB + recovery "
                  f"{mib(t['recovery_upload_bits']):.4f} MiB -> total "
                  f"{t['total_upload_vs_dense']:6.1%} of FedAvg")
    print(f"final_acc={res.final_acc:.3f}  wall={res.wall_s:.1f}s")
    if cfg.out_json:
        path = res.to_json(cfg.out_json)
        print(f"ledger written to {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
