"""Simulation configuration (the one object that names an experiment).

A :class:`SimConfig` fully determines a multi-round federated run: model,
dataset + partition, federated protocol, THGS/secure-aggregation mechanisms,
client sampling + dropout injection, evaluation cadence, checkpointing and
output paths. Two runs from the same config and seed are bit-identical
(sampling is counter-based per round, not sequential — see sampler.py), which
is what makes checkpoint/resume and the EXPERIMENTS.md protocols reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.dp import DPConfig
from repro.core.types import FedConfig, SecureAggConfig, THGSConfig

PARTITIONS = ("iid", "noniid", "dirichlet")
SAMPLERS = ("uniform", "weighted")
ACCOUNTINGS = ("paper", "tpu")
SHARD_CLIENTS = ("auto", "on", "off")
TOPOLOGIES = ("flat", "tree")
MODES = ("sync", "async")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Everything a `repro.sim.Simulation` needs, as one frozen record.

    Parameters
    ----------
    name : str
        Experiment name; stamped into results/ledger JSON.
    model, dataset : str
        Keys into ``models.paper_models.PAPER_MODELS`` / ``data.SPECS``.
    partition : {'iid', 'noniid', 'dirichlet'}
        Client data partition scheme; ``noniid`` is the paper's Non-IID-k
        (``noniid_k`` labels per client), ``dirichlet`` uses
        ``dirichlet_alpha``.
    rounds, n_clients, clients_per_round, local_steps, local_batch,
    local_lr, server_lr, algorithm, prox_mu
        The §5 federated protocol (mirrors ``core.types.FedConfig``).
    thgs : THGSConfig or None
        ``None`` runs the dense FedAvg/FedProx baseline.
    sa : SecureAggConfig
        Sparse-mask secure aggregation settings.
    codec : {'f32', 'int8', 'int4', '1bit'}
        Stream value wire codec (core/codecs.py, DESIGN.md §12); quantized
        codecs need ``thgs`` and reject ``sa.enabled`` (masks cancel only on
        the f32 grid).
    dp : DPConfig or None
        Distributed differential privacy (core/dp.py, DESIGN.md §15):
        per-client L2 clipping + grid-exact Gaussian noise under the pair
        masks, with the (ε, δ) accountant in the ledger. Needs ``thgs`` and
        the f32 codec; rejects ``mode='async'`` (noise is calibrated to a
        round-synchronous cohort) and ``weight_by_data_count`` (data-count
        weights break the clip-bound sensitivity analysis). ``None`` or an
        inactive config (clip=inf, sigma=0) is bit-identical to no DP.
    sampler : {'uniform', 'weighted'}
        Cohort sampling: uniform without replacement, or weighted by each
        client's local data count.
    weight_by_data_count : bool
        Aggregate with per-client weights equal to local data counts
        (client-side weighting — DESIGN.md §3); False averages uniformly.
    dropout_rate : float
        Per-round probability that a sampled client's upload is lost after
        mask agreement (Bonawitz dropout). At least one client always
        survives.
    eval_every : int
        Evaluate test accuracy every this many rounds.
    accounting : {'paper', 'tpu'}
        BitModel used for the round records logged by the server; the ledger
        reports both regardless.
    shard_clients : {'auto', 'on', 'off'}
        Client-parallel rounds over a 1-D ``clients`` device mesh
        (DESIGN.md §11). 'auto' shards when more than one local device
        evenly divides the cohort and falls back to the vmap path otherwise;
        'on' insists (raises without a usable mesh); 'off' disables.
        Sharded and serial rounds are bit-exact, so this is purely a
        throughput knob.
    topology : {'flat', 'tree'}
        Aggregation topology (DESIGN.md §13). 'tree' splits the decode over
        ``tree_groups`` sub-aggregators, each owning a contiguous index range
        of the dense buffer — bit-exact with 'flat' (another pure throughput
        knob). Requires ``thgs``.
    tree_groups : int
        Sub-aggregator count for 'tree'; 0 picks ~sqrt(cohort)
        (launch.mesh.default_tree_groups).
    mode : {'sync', 'async'}
        'async' runs FedBuff-style buffered updates (DESIGN.md §13): each
        server step aggregates ``buffer_size`` reports trained on stale
        parameter versions (simulated staleness drawn counter-based, at most
        ``max_staleness`` steps old) with weights ``(1+tau)^-0.5``. Requires
        ``thgs``; rejects ``sa.enabled`` (masks are agreed
        round-synchronously) and ``dropout_rate > 0`` (a buffer only ever
        holds arrived reports).
    buffer_size : int
        Async buffer size B (reports per server update); 0 uses
        ``clients_per_round``.
    max_staleness : int
        Upper bound on simulated staleness (also clamped by the number of
        parameter versions that exist yet).
    ckpt_dir : str, optional
        Directory for checkpoint/resume through ``checkpoint.store``;
        ``None`` disables checkpointing.
    ckpt_every : int
        Checkpoint cadence in rounds (0 = only implicit final state).
    out_json : str, optional
        Path the CLI writes the ledger/result JSON to.
    """

    name: str = "sim"
    # model + data
    model: str = "mnist_mlp"
    dataset: str = "mnist"
    partition: str = "iid"
    noniid_k: int = 4
    dirichlet_alpha: float = 0.5
    n_train: int = 4000
    n_test: int = 800
    # federated protocol (paper §5)
    rounds: int = 30
    n_clients: int = 20
    clients_per_round: int = 5
    local_steps: int = 5
    local_batch: int = 50
    local_lr: float = 0.05
    server_lr: float = 1.0
    algorithm: str = "fedavg"
    prox_mu: float = 0.0
    # mechanisms
    thgs: Optional[THGSConfig] = None
    sa: SecureAggConfig = SecureAggConfig(enabled=False)
    # stream wire codec (core/codecs.py, DESIGN.md §12): 'f32' passthrough or
    # 'int8'/'int4'/'1bit' quantized values + delta-packed indices; non-f32
    # requires thgs and rejects secure aggregation (validate())
    codec: str = "f32"
    # distributed DP (core/dp.py, DESIGN.md §15): None = off
    dp: Optional[DPConfig] = None
    # scheduling
    sampler: str = "uniform"
    weight_by_data_count: bool = False
    dropout_rate: float = 0.0
    eval_every: int = 3
    seed: int = 0
    # device sharding: 'auto' partitions the cohort over local devices when
    # >1 device evenly divides clients_per_round (DESIGN.md §11); 'off' pins
    # the single-device vmap path; 'on' requires a usable clients mesh and
    # raises when none exists (tests/CI use it to prove the path ran)
    shard_clients: str = "auto"
    # aggregation topology (DESIGN.md §13): 'tree' is bit-exact with 'flat'
    topology: str = "flat"
    tree_groups: int = 0       # 0 = auto (~sqrt cohort)
    # async (FedBuff-style) buffered updates (DESIGN.md §13)
    mode: str = "sync"
    buffer_size: int = 0       # 0 = clients_per_round
    max_staleness: int = 4
    # accounting + I/O
    accounting: str = "paper"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    out_json: Optional[str] = None

    def fed(self) -> FedConfig:
        """The ``core``-layer federated config this simulation drives."""
        return FedConfig(
            n_clients=self.n_clients,
            clients_per_round=self.clients_per_round,
            local_steps=self.local_steps,
            local_batch=self.local_batch,
            local_lr=self.local_lr,
            server_lr=self.server_lr,
            prox_mu=self.prox_mu,
            rounds=self.rounds,
            algorithm=self.algorithm,
        )

    def validate(self) -> None:
        if self.partition not in PARTITIONS:
            raise ValueError(f"partition must be one of {PARTITIONS}, "
                             f"got {self.partition!r}")
        if self.sampler not in SAMPLERS:
            raise ValueError(f"sampler must be one of {SAMPLERS}, "
                             f"got {self.sampler!r}")
        if self.accounting not in ACCOUNTINGS:
            raise ValueError(f"accounting must be one of {ACCOUNTINGS}, "
                             f"got {self.accounting!r}")
        if self.shard_clients not in SHARD_CLIENTS:
            raise ValueError(f"shard_clients must be one of {SHARD_CLIENTS}, "
                             f"got {self.shard_clients!r}")
        if not (1 <= self.clients_per_round <= self.n_clients):
            raise ValueError("need 1 <= clients_per_round <= n_clients, got "
                             f"{self.clients_per_round} vs {self.n_clients}")
        if not (0.0 <= self.dropout_rate <= 1.0):
            raise ValueError(f"dropout_rate in [0, 1], got {self.dropout_rate}")
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if self.algorithm not in ("fedavg", "fedprox"):
            raise ValueError(f"unknown algorithm {self.algorithm!r}")
        from repro.core.codecs import CODECS, reject_codec_with_masks
        if self.codec not in CODECS:
            raise ValueError(f"codec must be one of {CODECS}, "
                             f"got {self.codec!r}")
        if self.codec != "f32" and self.thgs is None:
            raise ValueError(
                f"codec {self.codec!r} requires THGS sparse streams "
                "(thgs=None runs the dense baseline, which has no stream "
                "wire to quantize)")
        # the shared guard (core/codecs.py, repro.lint RPL003)
        reject_codec_with_masks(self.codec, self.sa.enabled)
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                             f"got {self.topology!r}")
        if self.topology == "tree" and self.thgs is None:
            raise ValueError(
                "topology='tree' requires THGS sparse streams (dense rounds "
                "have no stream decode to shard across sub-aggregators)")
        if self.tree_groups < 0:
            raise ValueError(f"tree_groups must be >= 0 (0 = auto), "
                             f"got {self.tree_groups}")
        if self.dp is not None and self.dp.active:
            self.dp.validate()
            if self.thgs is None:
                raise ValueError(
                    "dp requires THGS sparse streams (the DP noise rides "
                    "the unified stream's transmitted slots)")
            # the shared guard (core/dp.py, the RPL003 discipline)
            from repro.core.dp import reject_codec_with_noise
            reject_codec_with_noise(self.codec, self.dp.sigma)
            if self.mode == "async":
                raise ValueError(
                    "dp cannot run with mode='async': the noise scale "
                    "sigma*clip/sqrt(C) is calibrated to a round-synchronous "
                    "cohort, which a streaming buffer breaks")
            if self.weight_by_data_count:
                raise ValueError(
                    "dp cannot run with weight_by_data_count: data-count "
                    "weights scale each client's contribution past the clip "
                    "bound, breaking the sensitivity analysis (use uniform "
                    "weights)")
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, "
                             f"got {self.mode!r}")
        if self.mode == "async":
            if self.thgs is None:
                raise ValueError(
                    "mode='async' requires THGS sparse streams (the async "
                    "path exercises the sparse-stream data plane)")
            if self.sa.enabled:
                raise ValueError(
                    "mode='async' cannot run secure aggregation: pair masks "
                    "are agreed round-synchronously among a known cohort, "
                    "which a streaming buffer breaks (DESIGN.md §13)")
            if self.dropout_rate > 0:
                raise ValueError(
                    "mode='async' has no dropout: a buffer only ever holds "
                    "reports that arrived (set dropout_rate=0)")
            B = self.buffer_size or self.clients_per_round
            if not (1 <= B <= self.n_clients):
                raise ValueError(
                    f"need 1 <= buffer_size <= n_clients, got {B} vs "
                    f"{self.n_clients}")
            if self.max_staleness < 0:
                raise ValueError(
                    f"max_staleness must be >= 0, got {self.max_staleness}")
            if self.shard_clients == "on":
                raise ValueError(
                    "mode='async' runs the serial update path; "
                    "shard_clients='on' cannot be honoured (use 'auto' or "
                    "'off')")
        elif self.buffer_size:
            raise ValueError("buffer_size is only meaningful with "
                             "mode='async'")
        if self.thgs is not None:
            self.thgs.validate()

    def replace(self, **kw) -> "SimConfig":
        """A copy with fields overridden (presets -> CLI overrides)."""
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        """JSON-safe dict (nested mechanism configs flattened to dicts)."""
        d = dataclasses.asdict(self)
        return d
