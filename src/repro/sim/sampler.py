"""Counter-based client sampling and dropout injection.

Every draw is keyed by ``(seed, round)`` through a fresh
``numpy.random.Generator`` — there is no sequential RNG state to carry between
rounds. That makes the schedule a pure function of the config: round ``t``'s
cohort is identical whether the run started at round 0 or resumed from a
checkpoint at round ``t - 1``, and two simulations with the same seed replay
the same participation trace (the seeded-determinism contract tested in
tests/test_sim.py).
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence

import numpy as np

# Domain-separation tags so the cohort draw and the dropout draw of the same
# round never consume the same stream.
_COHORT_TAG = 0xC0
_DROPOUT_TAG = 0xD0


class ClientSampler:
    """Deterministic per-round cohort sampler with dropout injection.

    Parameters
    ----------
    n_clients : int
        Total client population.
    cohort : int
        Clients selected every round. The cohort size is *fixed* for the whole
        run — that is the sim engine's compile-once contract (DESIGN.md §9):
        every round stacks exactly ``cohort`` client batches, so the jitted
        round program traces once and is reused.
    mode : {'uniform', 'weighted'}
        ``uniform`` samples without replacement with equal probability;
        ``weighted`` biases selection by ``weights`` (e.g. local data counts),
        still without replacement.
    weights : mapping of int -> float, optional
        Per-client selection weights for ``mode='weighted'``; missing clients
        default to 0 (never sampled). Weights must be non-negative and leave
        at least ``cohort`` clients with positive weight.
    dropout_rate : float
        Per-round probability that each sampled client's upload is lost
        *after* mask agreement. At least one client always survives.
    seed : int
        Root seed; all draws derive from ``(seed, tag, round)``.
    """

    def __init__(
        self,
        n_clients: int,
        cohort: int,
        *,
        mode: str = "uniform",
        weights: Optional[Mapping[int, float]] = None,
        dropout_rate: float = 0.0,
        seed: int = 0,
    ):
        if not 1 <= cohort <= n_clients:
            raise ValueError(f"need 1 <= cohort <= n_clients, "
                             f"got {cohort} vs {n_clients}")
        if mode not in ("uniform", "weighted"):
            raise ValueError(f"unknown sampler mode {mode!r}")
        self.n_clients = n_clients
        self.cohort = cohort
        self.mode = mode
        self.dropout_rate = float(dropout_rate)
        self.seed = int(seed)
        if mode == "weighted":
            w = np.zeros(n_clients, np.float64)
            for c, v in (weights or {}).items():
                if float(v) < 0.0:
                    raise ValueError(
                        f"weighted sampling got negative weight {v!r} for "
                        f"client {c}: weights must be >= 0 (they normalize "
                        "to selection probabilities)")
                w[int(c)] = float(v)
            if (w > 0).sum() < cohort:
                raise ValueError(
                    f"weighted sampling needs >= {cohort} clients with "
                    f"positive weight, got {(w > 0).sum()}")
            self._p = w / w.sum()
        else:
            self._p = None

    def _rng(self, tag: int, round_t: int) -> np.random.Generator:
        return np.random.default_rng([self.seed, tag, round_t])

    def cohort_for(self, round_t: int) -> np.ndarray:
        """The round's participants: sorted int array of exactly ``cohort``
        distinct client ids. Pure in ``(seed, round_t)``."""
        rng = self._rng(_COHORT_TAG, round_t)
        chosen = rng.choice(self.n_clients, size=self.cohort, replace=False,
                            p=self._p)
        return np.sort(chosen.astype(int))

    def dropouts_for(self, round_t: int, cohort: Sequence[int],
                     min_survivors: int = 1) -> list[int]:
        """Which of the round's participants drop after mask agreement.

        Each participant drops independently with ``dropout_rate``; the draw
        is then clamped so at least ``min_survivors`` participants stay alive
        (lowest-id drops are revived first). The default 1 is the FL
        invariant core/fedavg.py asserts; the engine raises it to the Shamir
        threshold ``sa.t_for(cohort)`` when secure aggregation is on, so an
        injected dropout never exceeds what Bonawitz recovery can unmask
        (below t the real protocol aborts the round — repro/secagg).
        The clamp does not perturb the underlying counter-based draw: the
        same (seed, round) always drops the same prefix-clamped set.
        """
        if self.dropout_rate <= 0.0:
            return []
        cohort = [int(c) for c in cohort]
        keep = max(1, int(min_survivors))
        rng = self._rng(_DROPOUT_TAG, round_t)
        drop = [c for c, u in zip(cohort, rng.random(len(cohort)))
                if u < self.dropout_rate]
        excess = len(drop) - (len(cohort) - keep)
        if excess > 0:
            drop = drop[excess:]
        return drop
