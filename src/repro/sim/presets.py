"""Named experiment presets for ``python -m repro.sim``.

Each preset is a complete :class:`~repro.sim.config.SimConfig`; the CLI (and
any caller) can override fields with ``preset.replace(...)``. The *protocols*
match EXPERIMENTS.md: ``*_quick`` variants shrink rounds/data for CI, the
unsuffixed ones are the paper-scale (CPU-reduced) runs the tables quote.
"""
from __future__ import annotations

from repro.core.dp import DPConfig
from repro.core.types import SecureAggConfig, THGSConfig
from repro.sim.config import SimConfig

# The paper's mechanism settings used across Table 2 (s = 0.01 regime).
_THGS = THGSConfig(s0=0.05, alpha=0.9, s_min=0.01)
_SA = SecureAggConfig(mask_ratio=0.01)


def _table2(quick: bool) -> dict:
    """The Table 2 protocol (Non-IID-4, 10 clients, 5 per round)."""
    return dict(
        partition="noniid", noniid_k=4, n_clients=10, clients_per_round=5,
        rounds=12 if quick else 28, n_train=1500 if quick else 4000,
        n_test=400, eval_every=2, local_steps=5, local_batch=50,
        local_lr=0.05)


PRESETS: dict[str, SimConfig] = {
    # the quickstart example: THGS + sparse-mask SA end to end
    "quickstart": SimConfig(
        name="quickstart", partition="noniid", noniid_k=4,
        n_clients=10, clients_per_round=5, rounds=30, n_train=4000,
        n_test=800, eval_every=5, thgs=_THGS, sa=_SA),
    # Table 2 "ours" arm (the headline 2.9%-18.9% upload ratio)
    "table2_quick": SimConfig(
        name="table2_quick", thgs=_THGS, sa=_SA,
        out_json="experiments/sim/table2_quick.json", **_table2(True)),
    "table2": SimConfig(
        name="table2", thgs=_THGS, sa=_SA,
        out_json="experiments/sim/table2.json", **_table2(False)),
    # Table 2 dense baselines, for side-by-side ledgers
    "table2_fedavg_quick": SimConfig(
        name="table2_fedavg_quick", thgs=None,
        sa=SecureAggConfig(enabled=False),
        out_json="experiments/sim/table2_fedavg_quick.json", **_table2(True)),
    # Fig. 1 single arm: flat s = 0.01, no SA, IID
    "fig1_s001_quick": SimConfig(
        name="fig1_s001_quick", partition="iid", n_clients=10,
        clients_per_round=5, rounds=10, n_train=1200, n_test=400,
        eval_every=2, sa=SecureAggConfig(enabled=False),
        thgs=THGSConfig(s0=0.01, alpha=1.0, s_min=0.01, time_varying=False),
        out_json="experiments/sim/fig1_s001_quick.json"),
    # secure-aggregation protocol smoke: multi-round with injected dropout —
    # every round runs the full repro/secagg phase sequence (DH + Shamir
    # shares), dropped clients' masks are reconstructed from survivor shares,
    # and the ledger reports the share/recovery traffic separately (the CI
    # runs this with --quick)
    "secagg_quick": SimConfig(
        name="secagg_quick", partition="noniid", noniid_k=4, n_clients=12,
        clients_per_round=6, rounds=8, n_train=1200, n_test=400,
        eval_every=2, local_steps=3, local_batch=32, thgs=_THGS,
        sa=SecureAggConfig(mask_ratio=0.01, threshold=0.6),
        dropout_rate=0.25, seed=11,
        out_json="experiments/sim/secagg_quick.json"),
    # dropout + weighted-cohort stress: exercises Bonawitz recovery and
    # data-count sampling/weighting in one run
    "dropout_quick": SimConfig(
        name="dropout_quick", partition="noniid", noniid_k=4, n_clients=12,
        clients_per_round=5, rounds=8, n_train=1200, n_test=400,
        eval_every=2, thgs=_THGS, sa=_SA, sampler="weighted",
        weight_by_data_count=True, dropout_rate=0.2,
        out_json="experiments/sim/dropout_quick.json"),
    # FedBuff-style async smoke (DESIGN.md §13): buffered staleness-weighted
    # updates, counter-based staleness draws, bit-identical resume — the CI
    # runs this with --quick and asserts the staleness facts on the ledger
    "async_quick": SimConfig(
        name="async_quick", partition="noniid", noniid_k=4, n_clients=12,
        clients_per_round=4, rounds=8, n_train=1200, n_test=400,
        eval_every=2, local_steps=3, local_batch=32, thgs=_THGS,
        sa=SecureAggConfig(enabled=False), mode="async", buffer_size=4,
        max_staleness=3, seed=5,
        out_json="experiments/sim/async_quick.json"),
    # hierarchical-topology smoke: the tree decode is bit-exact with flat
    # (tests/test_hierarchical_round.py), this preset keeps it on a
    # multi-round secagg+dropout path
    "tree_quick": SimConfig(
        name="tree_quick", partition="noniid", noniid_k=4, n_clients=12,
        clients_per_round=6, rounds=8, n_train=1200, n_test=400,
        eval_every=2, local_steps=3, local_batch=32, thgs=_THGS,
        sa=SecureAggConfig(mask_ratio=0.01, threshold=0.6),
        dropout_rate=0.25, seed=11, topology="tree", tree_groups=3,
        out_json="experiments/sim/tree_quick.json"),
    # distributed DP under secure aggregation (core/dp.py, DESIGN.md §15):
    # the secagg_quick protocol with per-client L2 clipping and discrete
    # Gaussian noise injected under the pair masks — the server only ever
    # sees the noised sum, the ledger carries the composed (epsilon, delta),
    # and the upload bits are unchanged (noise rides existing stream slots).
    # The CI runs this with --quick and asserts both facts.
    "dp_quick": SimConfig(
        name="dp_quick", partition="noniid", noniid_k=4, n_clients=12,
        clients_per_round=6, rounds=8, n_train=1200, n_test=400,
        eval_every=2, local_steps=3, local_batch=32, thgs=_THGS,
        sa=SecureAggConfig(mask_ratio=0.01, threshold=0.6),
        dropout_rate=0.25, seed=11,
        dp=DPConfig(clip=1.0, sigma=0.6, delta=1e-5),
        out_json="experiments/sim/dp_quick.json"),
    # tiny smoke config for tests/CI plumbing checks (~seconds)
    "ci_smoke": SimConfig(
        name="ci_smoke", partition="noniid", noniid_k=4, n_clients=6,
        clients_per_round=4, rounds=3, n_train=400, n_test=200,
        local_steps=2, local_batch=16, eval_every=1, thgs=_THGS, sa=_SA,
        out_json="experiments/sim/ci_smoke.json"),
}


# Codec sweeps: one Table-2-protocol run per wire codec (core/codecs.py,
# DESIGN.md §12). Every arm — including the f32 baseline — runs with secure
# aggregation OFF so the arms differ by wire codec alone (quantized codecs are
# rejected under secagg: masks cancel only on the f32 grid), which is what
# makes the ledger comparison in EXPERIMENTS.md / CI like-for-like.
SWEEPS: dict[str, tuple[str, ...]] = {
    "codec_sweep_quick": ("f32", "int8", "int4", "1bit"),
    "codec_sweep": ("f32", "int8", "int4", "1bit"),
}


def sweep_configs(name: str) -> dict[str, SimConfig]:
    """The per-codec arms of a named sweep, keyed by codec."""
    try:
        arm_codecs = SWEEPS[name]
    except KeyError:
        raise KeyError(
            f"unknown sweep {name!r}; available: {', '.join(sorted(SWEEPS))}"
        ) from None
    quick = name.endswith("_quick")
    return {
        codec: SimConfig(
            name=f"{name}_{codec}", thgs=_THGS,
            sa=SecureAggConfig(enabled=False), codec=codec, **_table2(quick))
        for codec in arm_codecs
    }


# Privacy-frontier sweeps: one dp_quick-protocol run per noise multiplier z
# (plus the z=0 "off" arm, which is bit-identical to a plain secagg run by
# construction — tests/test_dp.py). Arms share seed/protocol and differ by z
# alone; dropout is off so the frontier isn't confounded by survivor
# variance. The combined JSON maps arm -> full run summary, recording the
# privacy/accuracy/communication trade-off for EXPERIMENTS.md.
DP_SWEEPS: dict[str, tuple[float, ...]] = {
    "dp_frontier_quick": (0.0, 0.3, 0.6, 1.2),
    "dp_frontier": (0.0, 0.3, 0.6, 1.2),
}


def dp_sweep_configs(name: str) -> dict[str, SimConfig]:
    """The per-noise-multiplier arms of a named DP sweep, keyed by arm label
    ('off' for z=0, else 'z<value>')."""
    try:
        sigmas = DP_SWEEPS[name]
    except KeyError:
        raise KeyError(
            f"unknown dp sweep {name!r}; available: "
            f"{', '.join(sorted(DP_SWEEPS))}") from None
    quick = name.endswith("_quick")
    base = dict(
        partition="noniid", noniid_k=4, n_clients=12, clients_per_round=6,
        rounds=8 if quick else 24, n_train=1200 if quick else 4000,
        n_test=400, eval_every=2, local_steps=3, local_batch=32,
        thgs=_THGS, sa=SecureAggConfig(mask_ratio=0.01, threshold=0.6),
        dropout_rate=0.0, seed=11)
    out = {}
    for z in sigmas:
        label = "off" if z == 0.0 else f"z{z:g}"
        dp = None if z == 0.0 else DPConfig(clip=1.0, sigma=z, delta=1e-5)
        out[label] = SimConfig(name=f"{name}_{label}", dp=dp, **base)
    return out


def names() -> list[str]:
    return sorted(PRESETS)


def get(name: str) -> SimConfig:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown preset {name!r}; available: {', '.join(names())}"
        ) from None
