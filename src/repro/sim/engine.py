"""The multi-round federated simulation engine.

``Simulation`` owns everything `benchmarks/common.run_fl` used to improvise:
data synthesis + partitioning, the per-round cohort schedule (sampler.py),
dropout injection, driving ``core.fedavg.run_round``, the communication
ledger (ledger.py), streaming eval/metrics hooks, and checkpoint/resume
through ``checkpoint.store``.

Compile-once contract (DESIGN.md §9)
------------------------------------
The round program is jitted per *shape signature*: cohort size, batch shapes
and the per-leaf ``k``s. The scheduler therefore keeps the cohort shape fixed
— every round samples exactly ``clients_per_round`` clients, and a dropped
client still occupies its slot in the stacked batch (its upload is discarded
server-side, which is exactly the Bonawitz semantics: local compute happened,
the upload never arrived). With the cohort shape pinned, the only remaining
re-trace source is the time-varying ``k`` schedule, which THGSConfig already
quantizes to ``k_levels`` geometric levels. The seed driver re-traced whenever
the cohort size wobbled; this engine makes the fixed shape a checked invariant.

The fixed cohort shape is also what makes device sharding free: with
``shard_clients`` (default 'auto') the engine builds a 1-D ``clients`` mesh
over the local devices and ``run_round`` partitions the cohort across it
(DESIGN.md §11) — bit-exact with the single-device path, so results never
depend on the device count.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.core import costs
from repro.core.fedavg import (FederatedState, init_state, run_async_update,
                               run_round)
from repro.data import (client_batches, dirichlet, iid, make_dataset,
                        noniid_label_k)
from repro.data.datasets import SPECS
from repro.models.paper_models import PAPER_MODELS, accuracy, cross_entropy_loss
from repro.sim.config import SimConfig
from repro.sim.ledger import CommLedger
from repro.sim.sampler import ClientSampler

# hook(round_t, info) with info keys:
#   state, cohort, dropped, loss, record, acc (only on eval rounds)
RoundHook = Callable[[int, dict], None]


def publish_params_hook(publish_dir: str, every: int = 1) -> RoundHook:
    """A :data:`RoundHook` that publishes the post-round global params for
    serving subscribers (repro.serving, DESIGN.md §16).

    Publishes the bare params pytree — not the training state — via
    ``checkpoint.publish`` (atomic npz + manifest, manifest written last so
    its presence marks the step complete) at step ``round + 1``, every
    ``every`` rounds. This is the control-plane seam between training and
    serving: the trainer never talks to the server, it only drops complete
    checkpoints; the server's ``CheckpointWatcher`` polls them up.
    """
    def hook(round_t: int, info: dict) -> None:
        if (round_t + 1) % max(1, every) == 0:
            checkpoint.publish(publish_dir, round_t + 1,
                               info["state"].params)

    return hook


@dataclasses.dataclass
class SimResult:
    """Outcome of one simulation: metric trajectories + the comm ledger."""

    name: str
    rounds: int
    eval_every: int
    accuracies: list          # test accuracy, one entry per eval point
    losses: list              # federation-mean local loss, one per round
    wall_s: float
    ledger: CommLedger
    config: dict

    @property
    def final_acc(self) -> float:
        """Mean of the last three eval points (the Table 2 convergence acc)."""
        return float(np.mean(self.accuracies[-3:])) if self.accuracies else 0.0

    def rounds_to_reach(self, target_acc: float) -> Optional[int]:
        """First round (1-indexed, eval-cadence resolution) whose test
        accuracy reached ``target_acc``; None if never reached."""
        for i, a in enumerate(self.accuracies):
            if a >= target_acc:
                return (i + 1) * max(1, self.eval_every)
        return None

    def upload_bits_to_reach(self, target_acc: float,
                             accounting: str = "paper") -> Optional[int]:
        """Cumulative upload bits until ``target_acc`` (Table 2's
        rounds-to-target costing); None if the target was never reached."""
        r = self.rounds_to_reach(target_acc)
        if r is None:
            return None
        return self.ledger.upload_bits_through(r, accounting)

    def summary(self) -> dict:
        return {
            "name": self.name,
            "rounds": self.rounds,
            "eval_every": self.eval_every,
            "final_acc": self.final_acc,
            "accuracies": [float(a) for a in self.accuracies],
            "losses": [float(x) for x in self.losses],
            "wall_s": self.wall_s,
            "config": self.config,
            "ledger": self.ledger.summary(),
        }

    def to_json(self, path: str) -> str:
        return self.ledger.to_json(path, extra={
            "name": self.name,
            "rounds": self.rounds,
            "eval_every": self.eval_every,
            "final_acc": self.final_acc,
            "accuracies": [float(a) for a in self.accuracies],
            "losses": [float(x) for x in self.losses],
            "wall_s": self.wall_s,
            "config": self.config,
        })


class Simulation:
    """Config-driven multi-round federated simulation (see module docstring).

    Build once, ``run()`` to completion; ``run(resume=True)`` (the default)
    picks up from the latest checkpoint in ``cfg.ckpt_dir`` when one exists.
    """

    sim_mode = "sync"   # the cfg.mode this class implements (see simulate())

    def __init__(self, cfg: SimConfig):
        cfg.validate()
        if cfg.mode != self.sim_mode:
            raise ValueError(
                f"{type(self).__name__} runs mode={self.sim_mode!r} but the "
                f"config asks for mode={cfg.mode!r}; use simulate() (or "
                "AsyncSimulation directly) for async configs")
        self.cfg = cfg
        self.model = PAPER_MODELS[cfg.model]
        spec = SPECS[cfg.dataset]
        self.x, self.y = make_dataset(spec, cfg.n_train, seed=cfg.seed)
        self.xt, self.yt = make_dataset(spec, cfg.n_test, seed=cfg.seed + 1,
                                        train=False)
        if cfg.partition == "iid":
            self.parts = iid(self.y, cfg.n_clients, seed=cfg.seed)
        elif cfg.partition == "noniid":
            self.parts = noniid_label_k(self.y, cfg.n_clients, cfg.noniid_k,
                                        seed=cfg.seed)
        else:
            self.parts = dirichlet(self.y, cfg.n_clients,
                                   cfg.dirichlet_alpha, seed=cfg.seed)
        self.data_counts = {c: int(len(idx)) for c, idx in self.parts.items()}
        self.sampler = ClientSampler(
            cfg.n_clients, cfg.clients_per_round, mode=cfg.sampler,
            weights=self.data_counts if cfg.sampler == "weighted" else None,
            dropout_rate=cfg.dropout_rate, seed=cfg.seed)
        self.fed = cfg.fed()
        self.bits = (costs.PAPER_BITS if cfg.accounting == "paper"
                     else costs.TPU_BITS)
        self.loss_fn = cross_entropy_loss(self.model)
        self.client_weights = (self.data_counts if cfg.weight_by_data_count
                               else None)
        # injected dropout must stay within what the secure-aggregation
        # protocol can recover from: at least the Shamir threshold t of the
        # cohort has to survive (repro/secagg; below t the round would abort)
        self.min_survivors = (
            cfg.sa.t_for(cfg.clients_per_round)
            if cfg.thgs is not None and cfg.sa.enabled else 1)
        # client-parallel rounds: partition the (fixed-shape) cohort over a
        # 1-D clients mesh when the devices allow it (DESIGN.md §11)
        self.mesh = None
        if cfg.shard_clients != "off":
            from repro.launch.mesh import clients_mesh_for

            self.mesh = clients_mesh_for(cfg.clients_per_round)
            if cfg.shard_clients == "on" and self.mesh is None:
                raise RuntimeError(
                    "shard_clients='on' but no usable clients mesh: "
                    f"{len(jax.devices())} device(s) for a cohort of "
                    f"{cfg.clients_per_round} (need >1 devices evenly "
                    "dividing the cohort, e.g. XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8 on CPU)")
        self.ledger = CommLedger()

    # ----------------------------------------------------------------- state
    def _fresh_state(self) -> FederatedState:
        params = self.model.init(jax.random.key(self.cfg.seed))
        return init_state(params, self.fed)

    def _batches_for(self, round_t: int, cohort: Sequence[int]) -> dict:
        """Fixed-shape [steps, batch, ...] stacks for every cohort member.

        Seeded by (seed, round, client): resume-safe and cohort-order
        independent.
        """
        cfg = self.cfg
        out = {}
        for c in cohort:
            xb, yb = client_batches(
                self.x, self.y, self.parts[int(c)], cfg.local_batch,
                cfg.local_steps,
                seed=cfg.seed * 7919 + round_t * 1000 + int(c))
            out[int(c)] = (jnp.asarray(xb), jnp.asarray(yb))
        return out

    # ------------------------------------------------------------ checkpoint
    def _sidecar_path(self, step: int) -> str:
        return os.path.join(self.cfg.ckpt_dir, f"sim_{step:08d}.json")

    # the four hooks AsyncSimulation extends to persist its parameter-version
    # ring alongside params/residuals
    def _ckpt_tree(self, state: FederatedState) -> dict:
        return {"params": state.params, "residuals": state.residuals}

    def _ckpt_like(self, state: FederatedState, meta: dict) -> dict:
        return {"params": state.params, "residuals": state.residuals}

    def _load_ckpt_tree(self, state: FederatedState, tree: dict) -> None:
        state.params = tree["params"]
        state.residuals = tree["residuals"]

    def _sidecar_extra(self) -> dict:
        return {}

    def _save_ckpt(self, round_done: int, state: FederatedState,
                   accs: list, losses: list) -> None:
        checkpoint.save(self.cfg.ckpt_dir, round_done, self._ckpt_tree(state))
        sidecar = {
            "round": round_done,
            "client_losses": {str(c): float(v)
                              for c, v in state.losses.items()},
            "accuracies": [float(a) for a in accs],
            "losses": [float(x) for x in losses],
            "ledger_entries": self.ledger.summary()["entries"],
        }
        sidecar.update(self._sidecar_extra())
        # tmp + rename so a crash mid-write never leaves a truncated sidecar
        # shadowing the last good (npz, sidecar) pair
        path = self._sidecar_path(round_done)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(sidecar, f)
        os.replace(tmp, path)

    def _try_resume(self, state: FederatedState,
                    accs: list, losses: list) -> int:
        """Restore the latest checkpoint; returns the round to start from."""
        cfg = self.cfg
        if not cfg.ckpt_dir or not os.path.isdir(cfg.ckpt_dir):
            return 0
        # newest (npz, sidecar)-consistent pair: a crash between the npz
        # write and the sidecar write must not orphan the earlier good ones,
        # and a sidecar that exists but doesn't parse (truncated by a crash
        # predating the atomic write, or by disk corruption) counts as
        # missing — fall back to the next older pair instead of dying
        steps = sorted(
            (int(m.group(1)) for f in os.listdir(cfg.ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))), reverse=True)
        step, meta = None, None
        for s in steps:
            if not os.path.exists(self._sidecar_path(s)):
                continue
            try:
                with open(self._sidecar_path(s)) as f:
                    meta = json.load(f)
            except (ValueError, OSError) as e:
                import warnings

                warnings.warn(
                    f"unreadable checkpoint sidecar {self._sidecar_path(s)} "
                    f"({e}); falling back to an older checkpoint",
                    RuntimeWarning, stacklevel=2)
                continue
            step = s
            break
        if step is None:
            return 0
        if step > cfg.rounds:
            raise ValueError(
                f"checkpoint at round {step} > rounds={cfg.rounds}; "
                "refusing to resume past the configured horizon")
        tree = checkpoint.restore(
            cfg.ckpt_dir, step, like=self._ckpt_like(state, meta))
        self._load_ckpt_tree(state, tree)
        state.losses = {int(c): float(v)
                        for c, v in meta["client_losses"].items()}
        state.round = step
        accs[:] = meta["accuracies"]
        losses[:] = meta["losses"]
        self.ledger.entries = CommLedger.from_entry_dicts(
            meta["ledger_entries"]).entries
        return step

    # ------------------------------------------------------------------- run
    def run(self, *, resume: bool = True,
            hooks: Sequence[RoundHook] = ()) -> SimResult:
        cfg = self.cfg
        # fresh ledger per run: calling run() twice must not double-count
        # (and must not mutate a previously returned SimResult's ledger)
        self.ledger = CommLedger()
        state = self._fresh_state()
        accs: list = []
        losses: list = []
        start = self._try_resume(state, accs, losses) if resume else 0
        t0 = time.perf_counter()
        for r in range(start, cfg.rounds):
            cohort = self.sampler.cohort_for(r)
            # the compile-once contract: the stacked shapes never change
            assert len(cohort) == cfg.clients_per_round, (
                "fixed-cohort contract violated: "
                f"{len(cohort)} != {cfg.clients_per_round}")
            dropped = self.sampler.dropouts_for(
                r, cohort, min_survivors=self.min_survivors)
            batches = self._batches_for(r, cohort)
            state = run_round(
                state, batches, self.loss_fn, self.fed,
                cfg.thgs, cfg.sa, bits=self.bits,
                client_weights=self.client_weights, dropped=dropped,
                mesh=self.mesh, codec=cfg.codec,
                topology=cfg.topology, tree_groups=cfg.tree_groups,
                dp=cfg.dp)
            rec = state.comm_log[-1]
            self.ledger.record(rec)
            loss = float(np.mean([state.losses[c] for c in batches]))
            losses.append(loss)
            info = {"state": state, "cohort": cohort, "dropped": dropped,
                    "loss": loss, "record": rec}
            if (r + 1) % max(1, cfg.eval_every) == 0:
                acc = accuracy(self.model, state.params, self.xt, self.yt)
                accs.append(acc)
                info["acc"] = acc
            if (cfg.ckpt_dir and cfg.ckpt_every
                    and (r + 1) % cfg.ckpt_every == 0):
                self._save_ckpt(r + 1, state, accs, losses)
            for hook in hooks:
                hook(r, info)
        self.state = state
        return SimResult(
            name=cfg.name,
            rounds=cfg.rounds,
            eval_every=cfg.eval_every,
            accuracies=accs,
            losses=losses,
            wall_s=time.perf_counter() - t0,
            ledger=self.ledger,
            config=cfg.to_dict(),
        )


class AsyncSimulation(Simulation):
    """FedBuff-style async simulation (DESIGN.md §13).

    Each server step ``t`` drains a buffer of ``B = cfg.buffer_size or
    cfg.clients_per_round`` *distinct* client reports. Report ``c`` trained
    from the parameter version ``tau_c`` server steps old, where the
    simulated staleness ``tau_c`` is drawn counter-based from
    ``(seed, 0xA5, t)`` — like the cohort sampler's draws, a pure function of
    the round index, which is what makes checkpoint/resume replay
    bit-identically (tests/test_async_sim.py). The server keeps a ring of
    the last ``max_staleness + 1`` parameter versions and applies the
    ``(1 + tau)^-0.5``-weighted aggregate through
    ``core.fedavg.run_async_update``; each update's taus land on the ledger
    entry as the ``staleness`` fact.
    """

    sim_mode = "async"
    _STALENESS_TAG = 0xA5

    def __init__(self, cfg: SimConfig):
        super().__init__(cfg)
        self.buffer = cfg.buffer_size or cfg.clients_per_round
        # B distinct reports per buffer: duplicate clients would clobber the
        # error-feedback residual write-back, so the buffer is sampled like a
        # cohort (without replacement); dropout is rejected by validate()
        self.sampler = ClientSampler(
            cfg.n_clients, self.buffer, mode=cfg.sampler,
            weights=self.data_counts if cfg.sampler == "weighted" else None,
            dropout_rate=0.0, seed=cfg.seed)
        self.mesh = None          # async runs the serial update path
        self.versions: list = []  # parameter ring, newest last

    def _staleness_for(self, round_t: int) -> list[int]:
        """Counter-based per-report staleness draws for server step
        ``round_t``: uniform over [0, min(t, ring, max_staleness)] — early
        steps cannot be staler than the number of versions that exist."""
        hi = min(round_t, len(self.versions) - 1, self.cfg.max_staleness)
        rng = np.random.default_rng(
            [self.cfg.seed, self._STALENESS_TAG, round_t])
        return [int(t) for t in rng.integers(0, hi + 1, size=self.buffer)]

    # ------------------------------------------------- checkpoint ring hooks
    def _ckpt_tree(self, state: FederatedState) -> dict:
        d = super()._ckpt_tree(state)
        d["ring"] = {str(i): v for i, v in enumerate(self.versions)}
        return d

    def _ckpt_like(self, state: FederatedState, meta: dict) -> dict:
        like = super()._ckpt_like(state, meta)
        like["ring"] = {str(i): state.params
                        for i in range(int(meta["ring_len"]))}
        return like

    def _load_ckpt_tree(self, state: FederatedState, tree: dict) -> None:
        super()._load_ckpt_tree(state, tree)
        ring = tree["ring"]
        self.versions = [ring[str(i)] for i in range(len(ring))]

    def _sidecar_extra(self) -> dict:
        return {"ring_len": len(self.versions)}

    # ------------------------------------------------------------------- run
    def run(self, *, resume: bool = True,
            hooks: Sequence[RoundHook] = ()) -> SimResult:
        cfg = self.cfg
        self.ledger = CommLedger()
        state = self._fresh_state()
        self.versions = [state.params]
        accs: list = []
        losses: list = []
        start = self._try_resume(state, accs, losses) if resume else 0
        t0 = time.perf_counter()
        for r in range(start, cfg.rounds):
            cohort = self.sampler.cohort_for(r)
            assert len(cohort) == self.buffer, (
                "fixed-buffer contract violated: "
                f"{len(cohort)} != {self.buffer}")
            taus = self._staleness_for(r)
            batches = self._batches_for(r, cohort)
            client_params = {int(c): self.versions[-1 - tau]
                             for c, tau in zip(cohort, taus)}
            state = run_async_update(
                state, batches, client_params, self.loss_fn, self.fed,
                cfg.thgs, bits=self.bits,
                staleness={int(c): tau for c, tau in zip(cohort, taus)},
                client_weights=self.client_weights, codec=cfg.codec,
                topology=cfg.topology, tree_groups=cfg.tree_groups)
            self.versions.append(state.params)
            if len(self.versions) > cfg.max_staleness + 1:
                self.versions = self.versions[-(cfg.max_staleness + 1):]
            rec = state.comm_log[-1]
            self.ledger.record(rec)
            loss = float(np.mean([state.losses[c] for c in batches]))
            losses.append(loss)
            info = {"state": state, "cohort": cohort, "dropped": (),
                    "staleness": taus, "loss": loss, "record": rec}
            if (r + 1) % max(1, cfg.eval_every) == 0:
                acc = accuracy(self.model, state.params, self.xt, self.yt)
                accs.append(acc)
                info["acc"] = acc
            if (cfg.ckpt_dir and cfg.ckpt_every
                    and (r + 1) % cfg.ckpt_every == 0):
                self._save_ckpt(r + 1, state, accs, losses)
            for hook in hooks:
                hook(r, info)
        self.state = state
        return SimResult(
            name=cfg.name,
            rounds=cfg.rounds,
            eval_every=cfg.eval_every,
            accuracies=accs,
            losses=losses,
            wall_s=time.perf_counter() - t0,
            ledger=self.ledger,
            config=cfg.to_dict(),
        )


def simulate(cfg: SimConfig, **run_kw) -> SimResult:
    """One-call convenience: build the right Simulation for ``cfg.mode``
    ('sync' -> Simulation, 'async' -> AsyncSimulation) and run it."""
    cls = AsyncSimulation if cfg.mode == "async" else Simulation
    return cls(cfg).run(**run_kw)
