"""repro.sim — the config-driven multi-round federated simulation engine.

The single driver for every paper-scale experiment (DESIGN.md §9): round
scheduling + client sampling + dropout injection (sampler.py), the
communication-cost ledger under both bit accountings (ledger.py), streaming
eval hooks and checkpoint/resume (engine.py), named experiment presets
(presets.py) and a CLI (``python -m repro.sim --preset table2_quick``).
"""
from repro.sim.config import SimConfig
from repro.sim.engine import (AsyncSimulation, SimResult, Simulation,
                              publish_params_hook, simulate)
from repro.sim.ledger import CommLedger, LedgerEntry, mib
from repro.sim.sampler import ClientSampler

__all__ = ["SimConfig", "SimResult", "Simulation", "AsyncSimulation",
           "simulate", "publish_params_hook", "CommLedger", "LedgerEntry",
           "mib", "ClientSampler"]
