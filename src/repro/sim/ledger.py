"""The communication-cost ledger: per-round bits, both accountings, JSON.

The server (core/fedavg.py) logs one ``CommRecord`` per round under a single
``BitModel``. The ledger keeps the slot-level facts of those records — per-leaf
top-k counts, per-pair mask slots, participant/survivor counts, model size —
and replays ``core.costs``'s Eq. 6-8 formulas under *both* accountings
(:data:`costs.PAPER_BITS` 96-bit sparse elements, :data:`costs.TPU_BITS`
float32 wire format), so one run yields both the paper-comparable and the
hardware-realistic Table 2 columns. Secure-aggregation control traffic
(phase-1 Shamir shares and the phase-3 recovery shares of dropped clients —
repro/secagg) is derived from the same facts and reported separately from
the gradient upload. ``CommLedger.totals() ==`` a hand-summed
``costs.round_record`` sequence by construction; tests/test_sim.py pins it.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Iterable, Optional, Sequence

from repro.core import costs
from repro.core.types import CommRecord

ACCOUNTINGS = {"paper": costs.PAPER_BITS, "tpu": costs.TPU_BITS}


def mib(bits: float) -> float:
    """Bits -> MiB (the unit of the paper's Table 2 and our summaries)."""
    return bits / 8 / 2**20


@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    """Slot-level facts of one round, independent of any BitModel.

    ``ks``/``k_masks`` are the per-leaf top-k and per-pair mask slot counts of
    a sparse round (empty for dense rounds); ``threshold`` is the round
    protocol's Shamir t (0 without secure aggregation). Bits under a given
    accounting are *derived*, never stored, so the two accountings cannot
    disagree with the facts — including the secure-aggregation control
    traffic (phase-1 shares, phase-3 recovery shares).
    """

    round: int
    n_clients: int
    n_survivors: int
    model_size: int
    ks: tuple
    k_masks: tuple
    threshold: int = 0
    codec: str = "f32"      # stream wire codec (core/codecs.py, DESIGN.md §12)
    leaf_sizes: tuple = ()  # per-leaf dense sizes (codec index widths)
    staleness: tuple = ()   # per-report taus of an async update (§13);
                            # empty on synchronous rounds
    dp_clip: float = 0.0    # DP per-client L2 clip S (0 = no clipping; §15)
    dp_sigma: float = 0.0   # DP cohort-sum noise multiplier z (0 = no noise)
    dp_delta: float = 0.0   # accountant target delta (0 = n/a)

    @property
    def sparse(self) -> bool:
        return bool(self.ks)

    @property
    def secagg(self) -> bool:
        """Did the round run sparse-mask secure aggregation?"""
        return any(km > 0 for km in self.k_masks)

    @property
    def dp(self) -> bool:
        """Did the round run the distributed-DP plane (clip and/or noise)?"""
        return self.dp_clip > 0.0 or self.dp_sigma > 0.0

    def dp_z_eff(self) -> float:
        """Survivor-aware effective noise multiplier of the round's sum.

        Each of the C participants adds ``z * S / sqrt(C)``; only the d
        survivors' streams reach the aggregate, so the realized sum noise is
        ``z * S * sqrt(d / C)`` — multiplier ``z * sqrt(d / C)`` against
        sensitivity S. Valid because every survivor releases (and noises)
        the SAME public common support (core/dp.py): every released
        coordinate of the sum carries all d survivors' noise, the released
        indices are data-independent, and clipping the error-feedback
        accumulator bounds the emitted subvector's L2 by S. 0.0 when the
        round carried no noise.
        """
        if self.dp_sigma <= 0.0 or self.n_clients <= 0:
            return 0.0
        return self.dp_sigma * math.sqrt(self.n_survivors / self.n_clients)

    def upload_bits(self, bits: costs.BitModel) -> int:
        """Round *gradient* upload total (Eq. 6 x survivors, or dense x
        survivors); control traffic is reported separately."""
        if self.sparse:
            return self.n_survivors * costs.upload_bits_sparse(
                self.ks, self.k_masks, max(self.n_clients - 1, 0), bits,
                codec=self.codec, leaf_sizes=self.leaf_sizes)
        return self.n_survivors * costs.upload_bits_dense(
            self.model_size, bits)

    def download_bits(self, bits: costs.BitModel) -> int:
        """Dense model broadcast to every participant (Eq. 8)."""
        return self.n_clients * costs.upload_bits_dense(self.model_size, bits)

    def dense_upload_bits(self, bits: costs.BitModel) -> int:
        """What dense FedAvg would have uploaded for the same cohort."""
        return self.n_clients * costs.upload_bits_dense(self.model_size, bits)

    def share_upload_bits(self, bits: costs.BitModel) -> int:
        """Phase-1 Shamir share uploads (repro/secagg protocol)."""
        if not self.secagg:
            return 0
        return costs.share_upload_bits(self.n_clients, bits)

    def share_download_bits(self, bits: costs.BitModel) -> int:
        """Phase-1 share relay, server -> holders."""
        return self.share_upload_bits(bits)

    def recovery_upload_bits(self, bits: costs.BitModel) -> int:
        """Phase-3 shares unmasking the round's dropped clients."""
        if not self.secagg:
            return 0
        return costs.recovery_upload_bits(
            self.threshold, self.n_clients - self.n_survivors, bits)

    def total_upload_bits(self, bits: costs.BitModel) -> int:
        """Gradient streams + all secure-aggregation control uploads."""
        return (self.upload_bits(bits) + self.share_upload_bits(bits)
                + self.recovery_upload_bits(bits))

    @classmethod
    def from_record(cls, rec: CommRecord) -> "LedgerEntry":
        return cls(round=rec.round, n_clients=rec.n_clients,
                   n_survivors=rec.n_survivors or rec.n_clients,
                   model_size=rec.model_size,
                   ks=tuple(rec.ks), k_masks=tuple(rec.k_masks),
                   threshold=int(rec.threshold),
                   codec=str(getattr(rec, "codec", "f32")),
                   leaf_sizes=tuple(getattr(rec, "leaf_sizes", ())),
                   staleness=tuple(
                       int(t) for t in getattr(rec, "staleness", ())),
                   dp_clip=float(getattr(rec, "dp_clip", 0.0)),
                   dp_sigma=float(getattr(rec, "dp_sigma", 0.0)),
                   dp_delta=float(getattr(rec, "dp_delta", 0.0)))


class CommLedger:
    """Accumulates per-round communication and emits run-level summaries.

    Usage: feed it every round's ``CommRecord`` (``record()`` or
    ``extend()``), then read ``totals(accounting)``, ``summary()`` or
    serialize with ``to_json()``. ``rounds_to_target`` utilities live on
    ``engine.SimResult`` which also owns the accuracy trajectory.
    """

    def __init__(self, entries: Optional[Sequence[LedgerEntry]] = None):
        self.entries: list[LedgerEntry] = list(entries or [])

    # ------------------------------------------------------------- ingestion
    def record(self, rec: CommRecord) -> LedgerEntry:
        if rec.model_size <= 0:
            raise ValueError(
                "CommRecord carries no slot-level facts (model_size == 0); "
                "was it built by costs.round_record/dense_round_record?")
        entry = LedgerEntry.from_record(rec)
        self.entries.append(entry)
        return entry

    def extend(self, recs: Iterable[CommRecord]) -> None:
        for rec in recs:
            self.record(rec)

    # --------------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self.entries)

    def totals(self, accounting: str = "paper") -> dict:
        """Run totals under one accounting.

        Returns a dict with ``upload_bits`` (gradient streams),
        ``download_bits``, ``dense_upload_bits`` (the FedAvg baseline for the
        same cohorts), the secure-aggregation control traffic
        (``share_upload_bits``, ``share_download_bits``,
        ``recovery_upload_bits`` — repro/secagg phases 1 and 3),
        ``total_upload_bits`` (gradient + control), ``upload_vs_dense`` (the
        paper's headline gradient-only ratio; 2.9%-18.9% at s = 0.01),
        ``total_upload_vs_dense`` (the same ratio with recovery traffic
        counted) and ``compression_x``.
        """
        bits = ACCOUNTINGS[accounting]
        up = sum(e.upload_bits(bits) for e in self.entries)
        down = sum(e.download_bits(bits) for e in self.entries)
        dense = sum(e.dense_upload_bits(bits) for e in self.entries)
        share_up = sum(e.share_upload_bits(bits) for e in self.entries)
        share_down = sum(e.share_download_bits(bits) for e in self.entries)
        recovery_up = sum(e.recovery_upload_bits(bits) for e in self.entries)
        total_up = up + share_up + recovery_up
        return {
            "accounting": accounting,
            "rounds": len(self.entries),
            "upload_bits": up,
            "download_bits": down,
            "dense_upload_bits": dense,
            "share_upload_bits": share_up,
            "share_download_bits": share_down,
            "recovery_upload_bits": recovery_up,
            "total_upload_bits": total_up,
            "upload_mib": mib(up),
            "dense_upload_mib": mib(dense),
            "upload_vs_dense": up / dense if dense else 0.0,
            "total_upload_vs_dense": total_up / dense if dense else 0.0,
            "compression_x": dense / up if up else 0.0,
        }

    def upload_bits_through(self, n_rounds: int,
                            accounting: str = "paper") -> int:
        """Cumulative upload bits over the first ``n_rounds`` rounds (the
        rounds-to-target-accuracy costing of Table 2)."""
        bits = ACCOUNTINGS[accounting]
        return sum(e.upload_bits(bits) for e in self.entries[:n_rounds])

    def per_round(self, accounting: str = "paper") -> list[dict]:
        bits = ACCOUNTINGS[accounting]
        return [
            {
                "round": e.round,
                "n_clients": e.n_clients,
                "n_survivors": e.n_survivors,
                "sparse": e.sparse,
                "secagg": e.secagg,
                "upload_bits": e.upload_bits(bits),
                "download_bits": e.download_bits(bits),
                "dense_upload_bits": e.dense_upload_bits(bits),
                "share_upload_bits": e.share_upload_bits(bits),
                "share_download_bits": e.share_download_bits(bits),
                "recovery_upload_bits": e.recovery_upload_bits(bits),
                "total_upload_bits": e.total_upload_bits(bits),
            }
            for e in self.entries
        ]

    def privacy(self, delta: Optional[float] = None) -> Optional[dict]:
        """The run's privacy accounting (DESIGN.md §15), or None without DP.

        Per-round Gaussian-mechanism (ε, δ) at the survivor-aware effective
        noise multiplier ``dp_z_eff``, plus the RDP composition across the
        whole horizon (core/dp.py) — adaptive composition is valid because
        each round's release is a clipped function of that client's own
        data plus already-released public state. Rounds with clipping but
        no noise make the composed ε infinite — clipping alone bounds
        sensitivity, it does not privatize. ``delta`` overrides the
        recorded target δ.
        """
        if not any(e.dp for e in self.entries):
            return None
        from repro.core import dp as dp_mod

        if delta is None:
            delta = next((e.dp_delta for e in self.entries
                          if e.dp_delta > 0.0), 1e-5)
        z_effs = [e.dp_z_eff() for e in self.entries]
        per_round = [
            {
                "round": e.round,
                "z": e.dp_sigma,
                "z_eff": z,
                "clip": e.dp_clip,
                "epsilon": dp_mod.round_epsilon(z, delta),
            }
            for e, z in zip(self.entries, z_effs)
        ]
        return {
            "delta": float(delta),
            "epsilon": dp_mod.compose_epsilon(z_effs, delta),
            "rounds": len(self.entries),
            "clip": max((e.dp_clip for e in self.entries), default=0.0),
            "noise_multiplier": max(
                (e.dp_sigma for e in self.entries), default=0.0),
            "per_round": per_round,
        }

    def summary(self) -> dict:
        """Both accountings side by side, plus the raw slot facts.

        DP runs additionally carry the ``privacy`` block — per-round and
        composed (ε, δ) next to the bit accounting; runs without DP omit the
        key, keeping their summaries byte-identical with pre-DP ledgers.
        """
        out = {
            "paper": self.totals("paper"),
            "tpu": self.totals("tpu"),
            "entries": [dataclasses.asdict(e) for e in self.entries],
        }
        priv = self.privacy()
        if priv is not None:
            out["privacy"] = priv
        return out

    # ----------------------------------------------------------------- (de)io
    def to_json(self, path: str, *, extra: Optional[dict] = None) -> str:
        """Serialize the ledger (and optional run metadata) for the benchmark
        tables; returns the path written."""
        payload = {"ledger": self.summary()}
        if extra:
            payload.update(extra)
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=2, default=float)
        os.replace(tmp, path)
        return path

    @classmethod
    def from_entry_dicts(cls, dicts: Sequence[dict]) -> "CommLedger":
        """Rebuild from ``summary()['entries']`` (checkpoint resume path)."""
        return cls([LedgerEntry(round=int(d["round"]),
                                n_clients=int(d["n_clients"]),
                                n_survivors=int(d["n_survivors"]),
                                model_size=int(d["model_size"]),
                                ks=tuple(int(k) for k in d["ks"]),
                                k_masks=tuple(int(k) for k in d["k_masks"]),
                                threshold=int(d.get("threshold", 0)),
                                codec=str(d.get("codec", "f32")),
                                leaf_sizes=tuple(
                                    int(s) for s in d.get("leaf_sizes", ())),
                                staleness=tuple(
                                    int(t) for t in d.get("staleness", ())),
                                dp_clip=float(d.get("dp_clip", 0.0)),
                                dp_sigma=float(d.get("dp_sigma", 0.0)),
                                dp_delta=float(d.get("dp_delta", 0.0)))
                    for d in dicts])
