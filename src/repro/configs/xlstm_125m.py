"""xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm", xlstm=True,
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0,  # assignment: gating/projection lives inside the cell (proj factor 2)
    vocab=50304, rope="none", tie_embeddings=True,
    source="arXiv:2405.04517",
)
