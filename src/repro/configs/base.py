"""Architecture config schema for the assigned model zoo.

Every assigned architecture gets one module in this package defining ``CONFIG``
with the exact dimensions from the assignment sheet (source cited per file), plus
``reduced()`` — the <=2-layer, d_model<=512, <=4-expert variant the smoke tests run
on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int            # routed experts
    top_k: int
    d_ff_expert: int
    n_shared: int = 0         # shared (always-on) experts, same d_ff_expert each
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2           # d_inner = expand * d_model
    head_dim: int = 64        # mamba2 SSD head dim P
    chunk: int = 256          # SSD chunk length
    n_groups: int = 1         # B/C groups


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""          # citation from the assignment sheet
    head_dim: Optional[int] = None           # default d_model // n_heads
    rope: str = "default"                    # default | 2d | none
    norm: str = "rmsnorm"                    # rmsnorm | layernorm
    act: str = "swiglu"                      # swiglu | gelu
    tie_embeddings: bool = False
    encoder_only: bool = False               # hubert: no causal mask, no decode
    # sliding-window attention (sub-quadratic variant for long_500k)
    window: Optional[int] = None             # None = full attention
    # VLM: one cross-attention layer after every `cross_attn_every` self-attn layers
    cross_attn_every: Optional[int] = None
    n_image_tokens: int = 1024               # stub frontend output length
    # hybrid (zamba2): mamba backbone + shared attention block cadence
    shared_attn_every: Optional[int] = None  # apply shared transformer block every N ssm layers
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    # xlstm: alternate sLSTM (even) / mLSTM (odd) blocks
    xlstm: bool = False
    dtype: str = "bfloat16"
    # decode KV cache storage: 'bf16' (default) | 'int8' (beyond-paper:
    # halves the decode memory/HBM term; dequantized on the fly)
    kv_dtype: str = "bf16"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    def supports_shape(self, shape_name: str) -> bool:
        """Which of the four assigned input shapes this arch runs (skips in DESIGN.md §5)."""
        if shape_name in ("decode_32k", "long_500k") and self.encoder_only:
            return False   # encoder-only: no decode step
        return True

    def long_context_variant(self) -> "ArchConfig":
        """long_500k needs sub-quadratic attention: SSM/hybrid archs are already
        O(1)-state; attention archs switch to the sliding-window variant."""
        if self.family in ("ssm",) and not self.xlstm:
            return self
        if self.window is not None or self.family == "ssm":
            return self
        return dataclasses.replace(self, window=8192)

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0, "GQA requires heads % kv == 0"
        if self.family == "moe":
            assert self.moe is not None
        if self.family in ("ssm", "hybrid") and not self.xlstm:
            assert self.ssm is not None


def reduced(cfg: ArchConfig, **over) -> ArchConfig:
    """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts, small vocab."""
    small: dict = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=min(cfg.d_model, 256),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else cfg.d_ff,
        vocab=min(cfg.vocab, 512),
        head_dim=64 if cfg.head_dim else None,
        n_image_tokens=min(cfg.n_image_tokens, 16),
        dtype="float32",
    )
    small["n_kv_heads"] = max(1, min(small["n_kv_heads"], small["n_heads"]))
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=min(cfg.moe.d_ff_expert, 128),
            n_shared=min(cfg.moe.n_shared, 1),
        )
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, chunk=16, head_dim=32)
    if cfg.cross_attn_every is not None:
        small["cross_attn_every"] = 1
    if cfg.shared_attn_every is not None:
        small["shared_attn_every"] = 1
    if cfg.window is not None:
        small["window"] = min(cfg.window, 32)
    small.update(over)
    return dataclasses.replace(cfg, **small)
