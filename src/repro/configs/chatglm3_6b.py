"""ChatGLM3-6B — RoPE 2d, GQA kv=2 [arXiv:2406.12793]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024, rope="2d",
    source="arXiv:2406.12793",
)
