"""DeepSeek-MoE-16B — 2 shared + 64 routed top-6, fine-grained [arXiv:2401.06066]."""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    moe=MoESpec(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    source="arXiv:2401.06066",
)
