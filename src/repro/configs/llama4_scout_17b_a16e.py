"""Llama-4-Scout-17B-16E — MoE top-1, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

Long-context layers use chunked attention; modeled as the sliding-window variant
for long_500k (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    moe=MoESpec(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
