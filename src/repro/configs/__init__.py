"""Assigned-architecture registry: ``get(name)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, MoESpec, SSMSpec, reduced

ARCHS = [
    "xlstm_125m",
    "chatglm3_6b",
    "yi_6b",
    "llama32_vision_90b",
    "hubert_xlarge",
    "zamba2_7b",
    "granite_20b",
    "deepseek_moe_16b",
    "yi_9b",
    "llama4_scout_17b_a16e",
]

_ALIAS = {
    "xlstm-125m": "xlstm_125m",
    "chatglm3-6b": "chatglm3_6b",
    "yi-6b": "yi_6b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "hubert-xlarge": "hubert_xlarge",
    "zamba2-7b": "zamba2_7b",
    "granite-20b": "granite_20b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "yi-9b": "yi_9b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
}


def get(name: str) -> ArchConfig:
    mod_name = _ALIAS.get(name, name)
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIAS)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ArchConfig = mod.CONFIG
    cfg.validate()
    return cfg


def all_archs() -> list[str]:
    return list(ARCHS)


__all__ = ["ArchConfig", "MoESpec", "SSMSpec", "reduced", "get", "all_archs", "ARCHS"]
