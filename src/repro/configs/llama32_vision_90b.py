"""Llama-3.2-Vision-90B — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].

100 decoder layers as 20 super-blocks of (4 self-attn + 1 cross-attn); the ViT
vision encoder + projector are a stub — input_specs() supplies image_embeds at
d_model (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, cross_attn_every=4, n_image_tokens=1024,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
