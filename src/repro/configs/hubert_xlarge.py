"""HuBERT-XLarge — encoder-only, wav2vec2 arch [arXiv:2106.07447].

Conv feature extractor is a stub (input_specs() supplies frame embeddings);
vocab=504 is the masked-prediction codebook. No decode shapes (encoder-only).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio", encoder_only=True,
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, norm="layernorm", act="gelu", rope="none",
    source="arXiv:2106.07447",
)
