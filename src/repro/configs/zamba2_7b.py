"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 Mamba2 layers in 9 super-blocks; one shared-weight attention+MLP block is
invoked after every 9 SSM layers (DESIGN.md §5 structural notes).
"""
from repro.configs.base import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, shared_attn_every=9,
    ssm=SSMSpec(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    source="arXiv:2411.15242",
)
