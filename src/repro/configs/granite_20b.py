"""Granite-20B-Code — llama-arch, MQA kv=1 [arXiv:2405.04324]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, act="gelu",  # gpt-bigcode lineage: gelu MLP, MQA
    source="arXiv:2405.04324",
)
