"""Sparse-mask secure aggregation as a first-class subsystem (paper §3.2,
Eq. 3-5, Alg. 2).

Bonawitz-style round protocol (protocol.py: DH pair secrets, Shamir shares,
survivor collection, dropped-mask reconstruction) over the counter-based,
kernel-backed mask data plane of core/streams.py + kernels/mask_prng.py.
Layering: secagg → core/kernels; the reference server (core/fedavg.py) pulls
the protocol in through a function-local import, and repro/sim drives it
multi-round with injected dropout. DESIGN.md §10 documents the phases and the
threat-model boundary (what is simulated vs real DH/Shamir).
"""
from repro.secagg.protocol import RoundProtocol, ThresholdError
from repro.secagg.shamir import PRIME, reconstruct, share

__all__ = ["RoundProtocol", "ThresholdError", "PRIME", "reconstruct", "share"]
