"""Shamir secret sharing over GF(2^61 - 1) — dropout recovery's control plane.

A client's DH private key is split into one share per cohort member with
threshold ``t``: any ``t`` shares reconstruct the key exactly (Lagrange
interpolation at 0), any ``t - 1`` are information-theoretically independent
of it. The field prime equals ``masks.DH_PRIME``, so private keys are field
elements as-is. All arithmetic is host-side Python integers — this is
control-plane traffic (one 64-bit field element per share on the wire,
accounted by core/costs), never tensor math.

Polynomial coefficients are derived deterministically from the share ``tag``
(sha256 counter stream) so the simulation is reproducible end-to-end; a real
deployment draws them from a CSPRNG — the boundary DESIGN.md §10 documents.
"""
from __future__ import annotations

import hashlib
from typing import Mapping, Sequence

# The Shamir field IS the DH group's field: private keys are shared as-is
# (no reduction can change them), so reconstruction returns the exact key.
from repro.core.masks import DH_PRIME as PRIME


def _coeff(tag: str, j: int) -> int:
    h = hashlib.sha256(f"shamir-coeff:{tag}:{j}".encode()).digest()
    return int.from_bytes(h[:16], "little") % PRIME


def share(secret: int, xs: Sequence[int], t: int, *, tag: str) -> dict:
    """Split ``secret`` into ``len(xs)`` shares with threshold ``t``.

    Parameters
    ----------
    secret : int
        The value to protect (reduced mod PRIME).
    xs : sequence of int
        Distinct nonzero evaluation points — one per share holder (the
        protocol uses ``client_id + 1``).
    t : int
        Reconstruction threshold: the polynomial has degree ``t - 1``.
    tag : str
        Domain-separation tag for the deterministic coefficient stream.

    Returns
    -------
    dict
        ``{x: poly(x) mod PRIME}`` — the share addressed to each holder.
    """
    xs = [int(x) for x in xs]
    if not 1 <= t <= len(xs):
        raise ValueError(f"need 1 <= t <= n shares, got t={t}, n={len(xs)}")
    if len(set(xs)) != len(xs) or any(x % PRIME == 0 for x in xs):
        raise ValueError("share points must be distinct and nonzero mod PRIME")
    coeffs = [secret % PRIME] + [_coeff(tag, j) for j in range(1, t)]
    out = {}
    for x in xs:
        acc = 0
        for c in reversed(coeffs):   # Horner
            acc = (acc * x + c) % PRIME
        out[x] = acc
    return out


def reconstruct(shares: Mapping[int, int]) -> int:
    """Lagrange interpolation at 0: recombine ``t`` (or more) shares.

    The caller enforces the threshold (protocol.ThresholdError); handed fewer
    than ``t`` genuine shares this still returns *a* field element, just one
    unrelated to the secret.
    """
    pts = [(int(x) % PRIME, int(y) % PRIME) for x, y in shares.items()]
    if len({x for x, _ in pts}) != len(pts):
        raise ValueError("duplicate share points")
    secret = 0
    for i, (xi, yi) in enumerate(pts):
        num = den = 1
        for j, (xj, _) in enumerate(pts):
            if i == j:
                continue
            num = (num * (-xj)) % PRIME
            den = (den * (xi - xj)) % PRIME
        secret = (secret + yi * num * pow(den, PRIME - 2, PRIME)) % PRIME
    return secret
