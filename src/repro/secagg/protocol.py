"""The Bonawitz-style secure-aggregation round protocol (paper Alg. 2).

One :class:`RoundProtocol` instance is one round's control plane, in four
phases (Bonawitz et al. 2017, adapted to the paper's sparse masks):

0. **Advertise keys** — every participant derives a DH key pair
   (masks.dh_private/dh_public) and publishes the public key.
1. **Share keys** — every participant Shamir-shares its *private* key among
   the cohort with threshold ``t = sa.t_for(C)`` (shamir.py). One share per
   peer crosses the wire (``C·(C-1)`` uploads + the server's relay), which
   core/costs accounts as ``share_upload_bits``/``share_download_bits``.
2. **Masked input collection** — the data plane: ``pair_seed_matrix`` hands
   the per-pair uint32 counter seeds to the batched encode
   (streams.encode_leaf_batch with ``pair_seeds``), which generates every
   pair mask of the round in one fused kernel/oracle pass.
3. **Unmasking** — the server collects the survivor set; for each dropped
   client it obtains ``t`` survivors' shares of that client's private key
   (``recovery_upload_bits``), reconstructs the key, re-derives the
   survivor→dropped pair seeds and cancels the now-unpaired masks
   (streams.dropout_cancel_streams_seeded). Fewer than ``t`` survivors ⇒
   :class:`ThresholdError` — the round aborts, exactly the real protocol's
   failure mode.

Hierarchical aggregation (DESIGN.md §13) needs **no change** to this
protocol: the tree's sub-aggregators are index-range shards of the dense
buffer, and pair masks cancel per-position — both endpoints of a pair mask
target the same positions, so their contributions route to the same
sub-aggregator and cancel inside its partial regardless of which clients the
pair spans. Pair seeds stay all-pairs over the cohort; dropout recovery
streams route by range exactly like client streams.

Threat-model boundary (DESIGN.md §10): DH and Shamir arithmetic are real
(modular exponentiation over GF(2^61-1); polynomial shares), their
*parameters* are toy and their randomness is derived deterministically from
the federation seed so runs reproduce. The reconstruction path genuinely
flows through share recombination — tests assert the recovered key and the
regenerated masks are bit-identical to the encode-time originals.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import masks
from repro.core.types import SecureAggConfig
from repro.secagg import shamir


class ThresholdError(RuntimeError):
    """Survivors fell below the Shamir threshold — the round cannot unmask."""


@dataclasses.dataclass(frozen=True)
class RoundProtocol:
    """One round's key agreement + secret sharing + recovery state.

    Build with :meth:`setup`; hand ``pair_seed_matrix()`` to the encode and,
    on dropout, ``recover_seeds()`` to the decode. ``t`` is the Shamir
    threshold; ``publics`` the advertised DH public keys; ``shares[u]`` maps
    holder point ``v + 1`` to holder ``v``'s share of ``u``'s private key.
    """

    sa: SecureAggConfig
    participants: tuple
    round_t: int
    t: int
    publics: Mapping[int, int]
    shares: Mapping[int, Mapping[int, int]]
    privs: Mapping[int, int]

    @classmethod
    def setup(cls, sa: SecureAggConfig, participants: Sequence[int],
              round_t: int) -> "RoundProtocol":
        """Phases 0-1: advertise key pairs, Shamir-share the private keys."""
        parts = tuple(sorted(int(c) for c in participants))
        if len(set(parts)) != len(parts):
            raise ValueError(f"duplicate participant ids: {parts}")
        if len(parts) < 2:
            raise ValueError("secure aggregation needs >= 2 participants")
        t = sa.t_for(len(parts))
        publics = {}
        shares = {}
        privs = {}
        points = [u + 1 for u in parts]
        for u in parts:
            x_u = masks.dh_private(sa.seed, u)
            privs[u] = x_u
            publics[u] = masks.dh_public(x_u)
            shares[u] = shamir.share(
                x_u, points, t, tag=f"{sa.seed}:{u}:{round_t}")
        return cls(sa=sa, participants=parts, round_t=round_t, t=t,
                   publics=publics, shares=shares, privs=privs)

    # ------------------------------------------------------------ data plane
    def pair_seed_matrix(self):
        """Phase 2 inputs: uint32 [C, C] counter seeds + Bonawitz signs.

        Derived from THIS protocol's key state (``privs``/``publics``) via
        masks.seed_matrix_from_keys — exactly the derivation
        ``recover_seeds`` replays from the Shamir-reconstructed key — so
        encode masks and recovery masks agree for any ``RoundProtocol``,
        including one built with keys that are not the ``sa.seed``-derived
        defaults (test doubles, a future CSPRNG setup). For ``setup()``-built
        instances the result is bit-identical to ``streams.pair_seed_matrix``
        (the protocol-free engine entry point).
        """
        parts = self.participants
        return masks.seed_matrix_from_keys(
            parts, [self.privs[u] for u in parts],
            [self.publics[u] for u in parts], self.round_t)

    # -------------------------------------------------------------- recovery
    def recover_seeds(self, survivors: Sequence[int],
                      dropped: Sequence[int]):
        """Phase 3: reconstruct dropped clients' keys, re-derive pair seeds.

        Returns a uint32 [C, C] matrix filled only at survivor↔dropped
        entries (everything else 0 — the decode's ``alive`` gate zeroes those
        pairs anyway). Raises :class:`ThresholdError` when the survivor set
        is smaller than ``t``, and ValueError when a reconstructed key does
        not match the advertised public key (a corrupted share).
        """
        surv = sorted(int(c) for c in survivors)
        drop = sorted(int(c) for c in dropped)
        known = set(self.participants)
        if not set(surv) <= known or not set(drop) <= known:
            raise ValueError("survivors/dropped must be round participants")
        if set(surv) & set(drop):
            raise ValueError("a client cannot both survive and drop")
        if len(surv) < self.t:
            raise ThresholdError(
                f"{len(surv)} survivors < threshold t={self.t}: "
                "the dropped clients' masks cannot be reconstructed")
        pos = {u: i for i, u in enumerate(self.participants)}
        C = len(self.participants)
        seeds = np.zeros((C, C), np.uint32)
        for d in drop:
            # the server queries exactly t survivors for their shares of d's
            # key — that is the recovery traffic costs.recovery_upload_bits
            # charges
            pts = {v + 1: self.shares[d][v + 1] for v in surv[:self.t]}
            x_d = shamir.reconstruct(pts)
            if masks.dh_public(x_d) != self.publics[d]:
                raise ValueError(
                    f"reconstructed key of client {d} fails the public-key "
                    "check — corrupted share?")
            for s in surv:
                secret = pow(self.publics[s], x_d, masks.DH_PRIME)
                sd = masks.seed_from_secret(secret, self.round_t)
                seeds[pos[s], pos[d]] = sd
                seeds[pos[d], pos[s]] = sd
        return jnp.asarray(seeds)

    # ------------------------------------------------------------ accounting
    @property
    def n_phase1_shares(self) -> int:
        """Shares crossing the wire in phase 1 (self-share stays local)."""
        C = len(self.participants)
        return C * (C - 1)

    def n_recovery_shares(self, n_dropped: int) -> int:
        """Shares uploaded by survivors to unmask ``n_dropped`` clients."""
        return self.t * n_dropped
