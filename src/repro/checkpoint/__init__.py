from repro.checkpoint.store import (latest_published_step, latest_step,
                                    publish, restore, save)

__all__ = ["save", "restore", "latest_step", "latest_published_step",
           "publish"]
