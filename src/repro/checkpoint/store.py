"""Sharding-aware numpy checkpointer.

Saves a pytree to ``<dir>/step_<n>.npz`` (leaves gathered to host, keyed by
flattened tree path) plus a tiny JSON manifest with dtypes/shapes. Restore
rebuilds the pytree and, when given a target sharding tree, ``jax.device_put``s
each leaf back onto the mesh — so a checkpoint written from a sharded train
state restores onto any mesh of the same logical shape.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "::"


def _flatten(tree: PyTree) -> dict[str, jax.Array]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree: PyTree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    # numpy's savez can't round-trip ml_dtypes (bfloat16): widen those to f32
    # on disk; restore() casts back per the manifest/`like` dtypes.
    def to_np(v):
        arr = np.asarray(jax.device_get(v))
        if arr.dtype not in (np.float64, np.float32, np.float16, np.int64,
                             np.int32, np.int16, np.int8, np.uint8, np.bool_,
                             np.uint32, np.uint64):
            arr = np.asarray(jax.device_get(v.astype(jax.numpy.float32)))
        return arr

    arrays = {k: to_np(v) for k, v in flat.items()}
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # .npz suffix so np.savez doesn't append another
    np.savez(tmp, **arrays)
    os.replace(tmp, path)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
    }
    # same tmp + rename discipline as the npz: a crash mid-dump must not
    # leave a truncated manifest masquerading as a complete checkpoint
    mpath = os.path.join(ckpt_dir, f"step_{step:08d}.json")
    mtmp = mpath + ".tmp"
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, mpath)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


# --------------------------------------------------- publish / subscribe
# The serving loop (repro/serving, DESIGN.md §16) treats a checkpoint
# directory as a single-writer/many-reader channel: the trainer *publishes*
# steps with ``publish`` (plain ``save`` — the manifest is written last and
# atomically, so its presence marks the step complete) and readers poll
# ``latest_published_step``, which only surfaces steps whose manifest both
# exists and parses. A crash mid-publish (npz present, manifest absent) or a
# corrupted manifest (truncated by something that bypassed the tmp+replace
# discipline) leaves the step invisible — subscribers stay on the last good
# one instead of dying inside ``restore``.

def publish(ckpt_dir: str, step: int, tree: PyTree) -> str:
    """Atomically publish ``tree`` as ``step`` for polling subscribers."""
    return save(ckpt_dir, step, tree)


def _manifest_ok(ckpt_dir: str, step: int) -> bool:
    mpath = os.path.join(ckpt_dir, f"step_{step:08d}.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, ValueError):
        return False
    return isinstance(manifest, dict) and manifest.get("step") == step


def latest_published_step(ckpt_dir: str,
                          after: Optional[int] = None) -> Optional[int]:
    """Newest *complete* step in ``ckpt_dir`` — npz present AND manifest
    present and parseable — or None. With ``after``, only steps strictly
    greater count (the subscriber's "anything new since step N?" poll)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(
        (int(m.group(1)) for f in os.listdir(ckpt_dir)
         if (m := re.match(r"step_(\d+)\.npz$", f))), reverse=True)
    for s in steps:
        if after is not None and s <= after:
            return None          # sorted newest-first: nothing newer is left
        if _manifest_ok(ckpt_dir, s):
            return s
    return None


def restore(ckpt_dir: str, step: int, like: PyTree,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Rebuild the pytree of ``like``'s structure from disk; optionally place
    each leaf with the matching sharding from ``shardings``."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(flat_like))
    leaves = []
    for (pth, leaf), shd in zip(flat_like, shard_leaves):
        key = _SEP.join(str(p) for p in pth)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        jarr = jax.numpy.asarray(arr).astype(leaf.dtype)  # jnp knows bf16
        leaves.append(jax.device_put(jarr, shd) if shd is not None
                      else jarr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
