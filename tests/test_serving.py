"""Serving-loop tests: hot-swap correctness, pub/sub crash safety, the
batched server, greedy decode seeding, metrics schema, and the in-process
train+serve CLI smoke (DESIGN.md §16)."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, configs, serving
from repro.data import make_lm_tokens
from repro.launch.serve import greedy_generate, make_prefill_step, next_token
from repro.models import transformer as tf
from repro.models.paper_models import PAPER_MODELS

MODEL = PAPER_MODELS["mnist_mlp"]


def _params(seed: int):
    return MODEL.init(jax.random.key(seed))


def _payloads(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    return rng.randn(n, *MODEL.input_shape).astype(np.float32)


# ------------------------------------------------------------- greedy decode
@pytest.fixture(scope="module")
def lm_setup():
    cfg = configs.reduced(configs.get("yi_6b"))
    params = tf.init_params(cfg, jax.random.key(0))
    prompts, _ = make_lm_tokens(cfg.vocab, 2, 12, seed=3)
    return cfg, params, jnp.asarray(prompts)


def test_next_token_2d_3d_agree(lm_setup):
    cfg, params, prompts = lm_setup
    prefill = jax.jit(make_prefill_step(cfg, 24))
    logits, _ = prefill(params, prompts)
    assert logits.ndim == 3
    t3 = next_token(logits)
    t2 = next_token(logits[:, -1, :])
    assert t3.shape == (prompts.shape[0], 1)
    assert t3.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(t3), np.asarray(t2))


def test_greedy_generate_seeded(lm_setup):
    cfg, params, prompts = lm_setup
    cache_len = prompts.shape[1] + 4 + 8
    out1 = greedy_generate(params, cfg, prompts, 4, cache_len)
    out2 = greedy_generate(params, cfg, prompts, 4, cache_len)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # the first generated token IS the argmax over the prefill's last logits
    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    logits, _ = prefill(params, prompts)
    first = np.argmax(np.asarray(logits[:, -1, :]), axis=-1)
    np.testing.assert_array_equal(np.asarray(out1[:, 0]), first)


# ----------------------------------------------------------------- hot swap
def _served_logits(server, payload):
    t = server.submit(payload)
    server.step(block=True)
    return np.asarray(t.wait(30.0))


def test_hot_swap_bit_identity(tmp_path):
    """Publish step 2 while serving step 1: post-swap logits must be
    bit-identical to a cold server restored from checkpoint 2."""
    d = str(tmp_path)
    p1, p2 = _params(1), _params(2)
    checkpoint.publish(d, 1, p1)
    x = _payloads(1)[0]

    metrics = serving.ServingMetrics()
    buffers = serving.WeightBuffers(p1, step=1)
    watcher = serving.CheckpointWatcher(d, p1, buffers, metrics=metrics)
    adapter = serving.ClassifierAdapter(MODEL, 4)
    server = serving.InferenceServer(adapter, watcher=watcher,
                                     metrics=metrics)
    before = _served_logits(server, x)

    checkpoint.publish(d, 2, p2)          # trainer finishes round 2
    assert watcher.poll_once() == 2       # staged off the serve path
    assert buffers.active_step == 1       # old weights still serving
    after = _served_logits(server, x)     # step() swaps between batches
    assert buffers.active_step == 2
    assert metrics.swap_steps == [2]

    cold = serving.InferenceServer(
        serving.ClassifierAdapter(MODEL, 4),
        checkpoint.restore(d, 2, like=p1))
    expect = _served_logits(cold, x)
    np.testing.assert_array_equal(after, expect)   # bit-identical
    assert not np.array_equal(before, after)       # and actually swapped


def test_truncated_manifest_keeps_last_good(tmp_path):
    """A crash mid-publish (npz there, manifest truncated or missing) leaves
    subscribers on the last complete checkpoint."""
    d = str(tmp_path)
    p1, p2 = _params(1), _params(2)
    checkpoint.publish(d, 1, p1)
    buffers = serving.WeightBuffers(p1, step=0)
    watcher = serving.CheckpointWatcher(d, p1, buffers)
    assert watcher.poll_once() == 1
    assert watcher.maybe_swap() == 1

    # crash A: manifest truncated mid-json.dump (bypassing tmp+replace)
    checkpoint.publish(d, 2, p2)
    with open(os.path.join(d, "step_00000002.json"), "w") as f:
        f.write('{"step": 2, "lea')
    # crash B: npz written, manifest never got there at all
    shutil.copy(os.path.join(d, "step_00000002.npz"),
                os.path.join(d, "step_00000003.npz"))

    assert checkpoint.latest_published_step(d) == 1
    assert checkpoint.latest_published_step(d, after=1) is None
    assert watcher.poll_once() is None
    assert buffers.active_step == 1       # still on the last good step

    # the trainer retries the publish -> step becomes visible again
    checkpoint.publish(d, 2, p2)
    assert checkpoint.latest_published_step(d) == 2
    assert watcher.poll_once() == 2


def test_swap_requires_staged():
    buffers = serving.WeightBuffers(_params(0))
    with pytest.raises(RuntimeError):
        buffers.swap()
    buffers.stage(5, _params(1))
    pause = buffers.swap()
    assert buffers.active_step == 5 and pause >= 0.0


# ------------------------------------------------------------------- server
def test_server_pads_partial_batches():
    params = _params(0)
    adapter = serving.ClassifierAdapter(MODEL, 8)
    server = serving.InferenceServer(adapter, params)
    rows = _payloads(3, seed=7)
    tickets = [server.submit(r) for r in rows]
    served = server.step(block=True)
    assert served == 3
    # expectation from the SAME jitted callable on the padded stack
    stack = np.concatenate(
        [rows, np.zeros((5, *MODEL.input_shape), np.float32)])
    expect = adapter.infer(params, jnp.asarray(stack))
    for i, t in enumerate(tickets):
        np.testing.assert_array_equal(t.wait(30.0), expect[i])
    doc = server.metrics.summary()
    assert doc["batches"] == {"count": 1, "mean_fill": 3.0}


class _ExplodingAdapter:
    max_batch = 4
    request_shape = MODEL.input_shape
    request_dtype = np.float32

    def infer(self, params, stack):
        raise RuntimeError("kaboom")

    def tokens_per_request(self):
        return 0


def test_server_records_adapter_errors():
    server = serving.InferenceServer(_ExplodingAdapter(), _params(0))
    gen = serving.LoadGenerator(server, _payloads(2), qps=1000.0,
                                wait_timeout_s=5.0)
    gen.run(n_requests=2)
    server.drain()
    errors = gen.drain()
    assert errors == 2
    doc = server.metrics.summary()
    assert doc["requests"]["errors"] == 2
    assert doc["requests"]["served"] == 0
    assert serving.validate_metrics(doc) == []   # still reconciles


def test_server_needs_params_or_watcher():
    with pytest.raises(ValueError):
        serving.InferenceServer(serving.ClassifierAdapter(MODEL, 2))


# ------------------------------------------------------------------ metrics
def test_metrics_document_validates():
    m = serving.ServingMetrics(offered_qps=10.0)
    for i in range(3):
        m.record_submit()
    m.record_batch(3, 0, 1)
    for i in range(3):
        m.record_served(100.0 + i, 0)
    m.record_swap(1, 2.0)
    m.wall_s = 0.5
    doc = m.summary()
    assert serving.validate_metrics(doc) == []
    assert doc["requests"] == {"submitted": 3, "served": 3, "errors": 0}
    assert doc["staleness"]["max"] == 1
    assert doc["checkpoints"]["served_steps"] == {"0": 3}


def test_metrics_validate_rejects_malformed():
    good = serving.ServingMetrics()
    good.record_submit()
    good.record_served(10.0, 0)
    doc = good.summary()
    assert serving.validate_metrics(doc) == []

    bad = json.loads(json.dumps(doc))
    bad["requests"]["served"] = 7                  # counts don't reconcile
    assert any("reconcile" in e for e in serving.validate_metrics(bad))
    bad2 = json.loads(json.dumps(doc))
    bad2["schema"] = "repro.serve/v0"
    assert serving.validate_metrics(bad2)
    bad3 = json.loads(json.dumps(doc))
    del bad3["swaps"]
    assert any("swaps" in e for e in serving.validate_metrics(bad3))
    bad4 = json.loads(json.dumps(doc))
    bad4["checkpoints"]["served_steps"] = {"0": 99}
    assert any("served_steps" in e for e in serving.validate_metrics(bad4))


def test_metrics_json_roundtrip(tmp_path):
    m = serving.ServingMetrics()
    m.record_submit()
    m.record_served(10.0, 0)
    path = str(tmp_path / "sub" / "metrics.json")
    m.to_json(path)
    doc = serving.load_metrics(path)
    assert doc["schema"] == serving.SCHEMA_VERSION
    with pytest.raises(ValueError):
        bad = str(tmp_path / "bad.json")
        with open(bad, "w") as f:
            json.dump({"schema": "nope"}, f)
        serving.load_metrics(bad)


# ---------------------------------------------------------------- CLI smoke
def test_train_serve_cli_smoke(tmp_path):
    """The whole loop in-process: train 2 rounds while serving, >=1 swap,
    zero errors, valid metrics document."""
    from repro.serving.__main__ import main

    out = str(tmp_path / "serve_metrics.json")
    rc = main(["--preset", "table2_quick", "--quick", "--rounds", "2",
               "--qps", "30", "--publish-dir", str(tmp_path / "pub"),
               "--out", out])
    assert rc == 0
    doc = serving.load_metrics(out)
    assert doc["requests"]["errors"] == 0
    assert doc["requests"]["served"] > 0
    assert doc["swaps"]["count"] >= 1
    assert np.isfinite(doc["latency_us"]["p99"])
