"""Eq. 1 (hierarchical) + Eq. 2 (time-varying) schedule properties."""

import pytest
pytest.importorskip("hypothesis")  # dev-only dep; tier-1 must collect without it
from hypothesis import given, settings, strategies as st

from repro.core.schedules import layer_rates, leaf_ks, round_rate
from repro.core.types import THGSConfig, quantize_k


@given(s0=st.floats(0.001, 1.0), alpha=st.floats(0.1, 1.0),
       n=st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_layer_rates_monotone_and_bounded(s0, alpha, n):
    s_min = s0 / 100
    cfg = THGSConfig(s0=s0, alpha=alpha, s_min=s_min)
    rates = layer_rates(cfg, n)
    assert len(rates) == n
    assert rates[0] == pytest.approx(s0)
    for a, b in zip(rates, rates[1:]):
        assert b <= a + 1e-12          # non-increasing (alpha <= 1)
        assert b >= s_min - 1e-12      # floored at s_min


def test_layer_rates_hits_floor():
    cfg = THGSConfig(s0=0.1, alpha=0.5, s_min=0.04)
    assert layer_rates(cfg, 4) == [0.1, 0.05, 0.04, 0.04]


@given(t=st.integers(0, 100), loss_prev=st.floats(0.1, 10),
       loss_curr=st.floats(0.1, 10))
@settings(max_examples=50, deadline=None)
def test_round_rate_clamped(t, loss_prev, loss_curr):
    cfg = THGSConfig(s0=0.1, alpha=0.9, s_min=0.01, alpha_t=0.8, r_min=0.001)
    r = round_rate(cfg, 0.1, t, 100, loss_prev, loss_curr)
    assert cfg.r_min <= r <= 1.0


def test_round_rate_decays_with_t():
    cfg = THGSConfig(s0=0.1, alpha=0.9, s_min=0.01, alpha_t=0.8, r_min=0.0001)
    r_early = round_rate(cfg, 0.1, 0, 100, 1.0, 1.0)
    r_late = round_rate(cfg, 0.1, 99, 100, 1.0, 1.0)
    assert r_late < r_early


def test_loss_improvement_raises_rate():
    # beta = (loss_prev - loss_curr)/loss_curr > 0 when improving (paper Alg. 2)
    cfg = THGSConfig(alpha_t=0.5)
    improving = round_rate(cfg, 0.1, 0, 100, 2.0, 1.0)
    flat = round_rate(cfg, 0.1, 0, 100, 1.0, 1.0)
    assert improving > flat


@given(k=st.integers(1, 10**6), size=st.integers(1, 10**7))
@settings(max_examples=100, deadline=None)
def test_quantize_k_bounds(k, size):
    k = min(k, size)
    kq = quantize_k(k, size, 16)
    assert 1 <= kq <= size


def test_leaf_ks_static_ints():
    cfg = THGSConfig(s0=0.1, alpha=0.8, s_min=0.01)
    ks = leaf_ks(cfg, [100, 10_000, 1_000_000])
    assert all(isinstance(k, int) and k >= 1 for k in ks)
