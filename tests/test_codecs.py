"""Quantized wire-format codecs (core/codecs.py, DESIGN.md §12).

Covers the value codecs (quantize/dequantize error bounds, exact pack round
trips), the delta-packed index stream, the encode-path integration (error
feedback absorbs quantization error, conservation holds to float rounding),
the secagg/dense guards at every layer, and the ledger accounting facts.
Property-test variants (hypothesis) live in test_codec_properties.py so this
file always runs.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import codecs, costs, streams
from repro.core.types import FedConfig, SecureAggConfig, THGSConfig

NON_F32 = [c for c in codecs.CODECS if c != "f32"]


# ------------------------------------------------------------- value codecs
@pytest.mark.parametrize("codec", NON_F32)
def test_quantize_roundtrip_error_bounded(codec):
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(5, 33)).astype(np.float32))
    q, scales = codecs.quantize_rows(vals, codec)
    vq = np.asarray(codecs.dequantize_rows(q, scales))
    err = np.abs(vq - np.asarray(vals))
    amax = np.abs(np.asarray(vals)).max(axis=-1, keepdims=True)
    if codec == "1bit":
        # sign carrier: |vq| == mean|v| per row, sign matches v
        mean = np.abs(np.asarray(vals)).mean(axis=-1, keepdims=True)
        np.testing.assert_allclose(np.abs(vq), np.broadcast_to(mean, vq.shape),
                                   rtol=1e-6)
        assert (np.sign(vq) == np.where(np.asarray(vals) >= 0, 1, -1)).all()
    else:
        qmax = {"int8": 127, "int4": 7}[codec]
        assert (err <= amax / qmax * 0.50001).all()


@pytest.mark.parametrize("codec", NON_F32)
def test_quantize_zero_rows_safe(codec):
    vals = jnp.zeros((3, 16), jnp.float32)
    q, scales = codecs.quantize_rows(vals, codec)
    vq = np.asarray(codecs.dequantize_rows(q, scales))
    assert np.isfinite(vq).all()
    np.testing.assert_array_equal(vq, 0.0)


@pytest.mark.parametrize("codec", NON_F32)
@pytest.mark.parametrize("k,m", [(1, 2), (7, 50), (17, 1000), (32, 4096)])
def test_pack_stream_roundtrip_exact(codec, k, m):
    """Delta-packed indices and bit-packed values survive the wire exactly."""
    rng = np.random.default_rng(k * 1000 + m)
    cols = np.stack([np.sort(rng.choice(m, size=k, replace=False))
                     for _ in range(3)]).astype(np.int32)
    vals = jnp.asarray(rng.normal(size=(3, k)).astype(np.float32))
    q, _ = codecs.quantize_rows(vals, codec)
    iw, vw = codecs.pack_stream_rows(jnp.asarray(cols), q, m=m, codec=codec)
    assert iw.dtype == jnp.uint32 and vw.dtype == jnp.uint32
    c2, q2 = codecs.unpack_stream_rows(iw, vw, k=k, m=m, codec=codec)
    np.testing.assert_array_equal(np.asarray(c2), cols)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))


def test_pack_stream_duplicate_cols_roundtrip():
    """Non-strict (duplicate) columns delta to 0 and still round-trip."""
    cols = jnp.asarray([[3, 3, 7, 7, 7]], jnp.int32)
    q = jnp.asarray([[1, -1, 2, -2, 3]], jnp.int32)
    iw, vw = codecs.pack_stream_rows(cols, q, m=100, codec="int8")
    c2, q2 = codecs.unpack_stream_rows(iw, vw, k=5, m=100, codec="int8")
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(cols))
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))


def test_index_width():
    assert codecs.index_width(2) == 1
    assert codecs.index_width(3) == 2
    assert codecs.index_width(1024) == 10
    assert codecs.index_width(1025) == 11


def test_wire_bits_formula():
    from repro.kernels.ref import packed_words
    k, m = 17, 1000
    for codec in NON_F32:
        expect = (32 * packed_words(k, codecs.index_width(m))
                  + 32 * packed_words(k, codecs.value_bits(codec))
                  + codecs.SCALE_BITS)
        assert codecs.wire_bits(k, m, codec) == expect
    with pytest.raises(ValueError):
        codecs.wire_bits(k, m, "f32")


# --------------------------------------------------------- encode-path stage
@pytest.mark.parametrize("codec", codecs.CODECS)
def test_encode_leaf_batch_codec_conservation(codec):
    """decode + summed residuals == summed updates: the quantization error is
    absorbed into error feedback, not lost."""
    rng = np.random.default_rng(2)
    C, size, nb, m = 4, 192, 3, 64
    g = jnp.asarray(rng.normal(size=(C, size)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(C, size)).astype(np.float32) * 0.1)
    sb, nr = streams.encode_leaf_batch(g, r, k=8, nb=nb, m=m, size=size,
                                       codec=codec)
    dense = streams.decode_leaf_batch(sb, nb=nb, m=m, size=size)
    tot = np.asarray(dense) + np.asarray(nr).sum(0)
    ref = np.asarray(g + r).sum(0)
    np.testing.assert_allclose(tot, ref, atol=1e-5)


def test_encode_leaf_batch_codec_weighted_conservation():
    rng = np.random.default_rng(3)
    C, size, nb, m = 4, 192, 3, 64
    g = jnp.asarray(rng.normal(size=(C, size)).astype(np.float32))
    r = jnp.zeros((C, size), jnp.float32)
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
    sb, nr = streams.encode_leaf_batch(g, r, k=8, nb=nb, m=m, size=size,
                                       codec="int8", weights=w)
    dense = streams.decode_leaf_batch(sb, nb=nb, m=m, size=size)
    tot = (np.asarray(dense)
           + (np.asarray(w)[:, None] * np.asarray(nr)).sum(0))
    ref = (np.asarray(w)[:, None] * np.asarray(g)).sum(0)
    np.testing.assert_allclose(tot, ref, atol=1e-5)


@pytest.mark.parametrize("codec", NON_F32)
def test_run_round_codec_converges(codec):
    """Quantized rounds converge like f32 on the linreg template (§12)."""
    from repro.core.fedavg import init_state, run_round

    dim = 40
    key = jax.random.key(0)
    true_w = jnp.linspace(1.0, 3.0, dim).reshape(dim, 1)

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] - y) ** 2)

    fed = FedConfig(n_clients=4, clients_per_round=4, local_steps=2,
                    local_batch=8, local_lr=0.05, rounds=6)
    thgs = THGSConfig(s0=0.5, alpha=1.0, s_min=0.3, time_varying=False)
    sa = SecureAggConfig(enabled=False)
    st = init_state({"w": jnp.zeros((dim, 1))}, fed)
    for r in range(fed.rounds):
        batches = {}
        for c in range(4):
            k = jax.random.fold_in(key, r * 10 + c)
            x = jax.random.normal(k, (2, 8, dim))
            batches[c] = (x, x @ true_w)
        st = run_round(st, batches, loss_fn, fed, thgs, sa, codec=codec)
    err = float(jnp.max(jnp.abs(st.params["w"] - true_w)))
    assert err < 2.0, err
    rec = st.comm_log[-1]
    assert rec.codec == codec
    assert rec.leaf_sizes == (dim,)


# ------------------------------------------------------------------- guards
def test_streams_rejects_codec_with_masks():
    with pytest.raises(ValueError, match="f32 .*grid"):
        streams.encode_leaf_batch(
            jnp.zeros((2, 8)), jnp.zeros((2, 8)), k=2, nb=1, m=8, size=8,
            codec="int8", k_mask=1)


def test_run_round_rejects_codec_with_secagg_and_dense():
    from repro.core.fedavg import init_state, run_round

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] - y) ** 2)

    fed = FedConfig(n_clients=2, clients_per_round=2, local_steps=1,
                    local_batch=4, local_lr=0.05, rounds=1)
    thgs = THGSConfig(s0=0.5, alpha=1.0, s_min=0.3, time_varying=False)
    st = init_state({"w": jnp.zeros((4, 1))}, fed)
    x = jnp.ones((1, 4, 4))
    batches = {0: (x, x @ jnp.ones((4, 1))), 1: (x, x @ jnp.ones((4, 1)))}
    with pytest.raises(ValueError, match="secure aggregation"):
        run_round(st, batches, loss_fn, fed, thgs,
                  SecureAggConfig(mask_ratio=0.1), codec="int8")
    with pytest.raises(ValueError, match="dense"):
        run_round(st, batches, loss_fn, fed, None,
                  SecureAggConfig(enabled=False), codec="int8")


def test_sim_config_rejects_codec_with_secagg_and_dense():
    from repro.sim.config import SimConfig

    thgs = THGSConfig(s0=0.05, alpha=0.9, s_min=0.01)
    with pytest.raises(ValueError, match="secure aggregation"):
        SimConfig(thgs=thgs, sa=SecureAggConfig(mask_ratio=0.01),
                  codec="int8").validate()
    with pytest.raises(ValueError, match="THGS"):
        SimConfig(thgs=None, sa=SecureAggConfig(enabled=False),
                  codec="int8").validate()
    with pytest.raises(ValueError, match="codec"):
        SimConfig(thgs=thgs, sa=SecureAggConfig(enabled=False),
                  codec="int16").validate()
    # the valid combination passes
    SimConfig(thgs=thgs, sa=SecureAggConfig(enabled=False),
              codec="int8").validate()


# --------------------------------------------------------------- accounting
def test_costs_codec_accounting_exact_and_invariant():
    ks, sizes = (1004,), (100352,)
    f32_paper = costs.upload_bits_sparse(ks, (0,), 0, costs.PAPER_BITS)
    for codec in NON_F32:
        b_paper = costs.upload_bits_sparse(
            ks, (0,), 0, costs.PAPER_BITS, codec=codec, leaf_sizes=sizes)
        b_tpu = costs.upload_bits_sparse(
            ks, (0,), 0, costs.TPU_BITS, codec=codec, leaf_sizes=sizes)
        # packed words ARE the wire: same bits under both accountings
        assert b_paper == b_tpu
        assert b_paper == sum(codecs.wire_bits(k, s, codec)
                              for k, s in zip(ks, sizes))
    # acceptance: int8 <= 1/3 of the f32 paper accounting
    b_int8 = costs.upload_bits_sparse(
        ks, (0,), 0, costs.PAPER_BITS, codec="int8", leaf_sizes=sizes)
    assert b_int8 <= f32_paper / 3


def test_costs_codec_guards():
    with pytest.raises(ValueError, match="secure aggregation"):
        costs.upload_bits_sparse((5,), (2,), 3, codec="int8",
                                 leaf_sizes=(100,))
    with pytest.raises(ValueError, match="leaf_sizes"):
        costs.upload_bits_sparse((5,), (0,), 3, codec="int8")


def test_ledger_codec_roundtrip_and_backcompat():
    from repro.sim.ledger import CommLedger, LedgerEntry

    rec = costs.round_record(1, 159010, (1004,), (0,), 5,
                             codec="int8", leaf_sizes=(100352,))
    led = CommLedger([LedgerEntry.from_record(rec)])
    # serialized entries -> rebuilt ledger -> identical totals
    led2 = CommLedger.from_entry_dicts(led.summary()["entries"])
    assert led2.totals("paper") == led.totals("paper")
    assert led2.totals("tpu") == led.totals("tpu")
    assert led2.entries[0].codec == "int8"
    # pre-codec checkpoint dicts default to f32
    old = {k: v for k, v in led.summary()["entries"][0].items()
           if k not in ("codec", "leaf_sizes")}
    led3 = CommLedger.from_entry_dicts([old])
    assert led3.entries[0].codec == "f32"
    assert led3.entries[0].leaf_sizes == ()


def test_sweep_configs_arms():
    from repro.sim import presets

    arms = presets.sweep_configs("codec_sweep_quick")
    assert set(arms) == {"f32", "int8", "int4", "1bit"}
    for codec, cfg in arms.items():
        assert cfg.codec == codec
        assert not cfg.sa.enabled  # like-for-like: secagg off in every arm
        cfg.validate()
    with pytest.raises(KeyError):
        presets.sweep_configs("nope")
