"""Checkpointer roundtrip + manifest."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.array(3, jnp.int32)}}
    path = checkpoint.save(str(tmp_path), 7, tree)
    assert path.endswith("step_00000007.npz")
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored = checkpoint.restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_step(tmp_path):
    assert checkpoint.latest_step(str(tmp_path)) is None
    t = {"x": jnp.zeros(1)}
    checkpoint.save(str(tmp_path), 1, t)
    checkpoint.save(str(tmp_path), 12, t)
    assert checkpoint.latest_step(str(tmp_path)) == 12


def test_manifest_crash_mid_dump_keeps_last_good_pair(tmp_path, monkeypatch):
    """A crash inside the step-2 manifest dump must not corrupt anything:
    the tmp + os.replace discipline means the target manifest is either the
    complete old file or absent, never truncated."""
    import json
    import os

    t = {"x": jnp.arange(4, dtype=jnp.float32)}
    checkpoint.save(str(tmp_path), 1, t)

    real_dump = json.dump

    def dump_partially_then_die(obj, fp, *a, **kw):
        fp.write('{"step": 2, "leaves": {"x"')      # partial JSON
        raise OSError("simulated crash mid-manifest-write")

    monkeypatch.setattr(json, "dump", dump_partially_then_die)
    try:
        checkpoint.save(str(tmp_path), 2, t)
        assert False, "expected the injected crash"
    except OSError:
        pass
    finally:
        monkeypatch.setattr(json, "dump", real_dump)

    # step-1 manifest still parses; step-2 manifest never appeared (the
    # partial bytes live only in the .tmp file, which resume ignores)
    with open(tmp_path / "step_00000001.json") as f:
        assert json.load(f)["step"] == 1
    assert not os.path.exists(tmp_path / "step_00000002.json")


def test_shape_mismatch_raises(tmp_path):
    t = {"x": jnp.zeros((2, 2))}
    checkpoint.save(str(tmp_path), 0, t)
    bad = {"x": jax.ShapeDtypeStruct((3, 2), jnp.float32)}
    try:
        checkpoint.restore(str(tmp_path), 0, bad)
        assert False, "expected ValueError"
    except ValueError:
        pass
