"""The simulation engine: sampler determinism, ledger exactness (Eq. 6-8),
end-to-end runs, checkpoint/resume (DESIGN.md §9)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs, schedules
from repro.core.fedavg import init_state, run_round
from repro.core.types import FedConfig, SecureAggConfig, THGSConfig
from repro.sim import (CommLedger, ClientSampler, SimConfig, Simulation,
                       presets)


# ------------------------------------------------------------------- sampler
def test_sampler_deterministic_and_fixed_cohort():
    a = ClientSampler(20, 5, dropout_rate=0.3, seed=7)
    b = ClientSampler(20, 5, dropout_rate=0.3, seed=7)
    seen = set()
    for t in range(12):
        ca, cb = a.cohort_for(t), b.cohort_for(t)
        np.testing.assert_array_equal(ca, cb)          # same seed -> same trace
        assert len(ca) == 5 and len(set(ca.tolist())) == 5
        assert all(0 <= c < 20 for c in ca)
        assert a.dropouts_for(t, ca) == b.dropouts_for(t, cb)
        seen.add(tuple(ca.tolist()))
    assert len(seen) > 1                               # rounds actually differ
    c = ClientSampler(20, 5, dropout_rate=0.3, seed=8)
    assert any(tuple(c.cohort_for(t).tolist()) not in seen for t in range(12))


def test_sampler_resume_invariance():
    # counter-based draws: round t's cohort does not depend on whether
    # earlier rounds were sampled from this instance
    a = ClientSampler(10, 3, seed=3)
    for t in range(6):
        a.cohort_for(t)
    b = ClientSampler(10, 3, seed=3)
    np.testing.assert_array_equal(a.cohort_for(6), b.cohort_for(6))


def test_sampler_weighted_bias():
    weights = {c: (1000.0 if c == 0 else 1.0) for c in range(10)}
    s = ClientSampler(10, 3, mode="weighted", weights=weights, seed=0)
    hits = sum(0 in s.cohort_for(t) for t in range(30))
    assert hits >= 28                                  # ~always sampled


def test_sampler_dropout_keeps_one_survivor():
    s = ClientSampler(8, 4, dropout_rate=1.0, seed=1)
    for t in range(5):
        cohort = s.cohort_for(t)
        dropped = s.dropouts_for(t, cohort)
        assert len(dropped) == len(cohort) - 1         # one always survives


def test_sampler_rejects_negative_weights():
    # a negative weight would silently skew (or crash) the normalized
    # selection probabilities many rounds later; fail at construction
    weights = {c: 1.0 for c in range(6)}
    weights[4] = -0.5
    with pytest.raises(ValueError, match="negative weight"):
        ClientSampler(6, 3, mode="weighted", weights=weights, seed=0)


# -------------------------------------------------------------------- ledger
def _linreg_model(dim):
    params = {"b": jnp.zeros((1,)), "w": jnp.zeros((dim, 1))}

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    return params, loss_fn


def test_ledger_totals_match_round_record_sum():
    """CommLedger totals == a hand-computed costs.round_record sum over a
    scripted 3-round run, including a round with a dropped client; the
    sparse/dense ratio matches Eq. 6-8 exactly under both accountings."""
    dim, C = 120, 4
    params, loss_fn = _linreg_model(dim)
    thgs = THGSConfig(s0=0.2, alpha=0.8, s_min=0.05, time_varying=False)
    sa = SecureAggConfig(mask_ratio=0.1)
    fed = FedConfig(n_clients=C, clients_per_round=C, local_steps=2,
                    local_batch=8, local_lr=0.01, rounds=3)
    st = init_state(params, fed)
    key = jax.random.key(0)
    dropped_per_round = [(), (), (2,)]
    for r in range(3):
        batches = {}
        for c in range(C):
            k = jax.random.fold_in(key, r * 100 + c)
            x = jax.random.normal(k, (2, 8, dim))
            batches[c] = (x, x @ jnp.ones((dim, 1)) + 0.1)
        st = run_round(st, batches, loss_fn, fed, thgs, sa,
                       dropped=dropped_per_round[r])

    ledger = CommLedger()
    ledger.extend(st.comm_log)
    assert len(ledger) == 3

    # hand-computed expectation straight from Eq. 6-8 (core/costs),
    # INCLUDING the secure-aggregation control traffic: phase-1 Shamir
    # shares every round, phase-3 recovery shares on the dropout round
    leaves = jax.tree_util.tree_leaves(params)
    sizes = [x.size for x in leaves]
    model_size = sum(sizes)
    ks = schedules.leaf_ks(thgs, sizes)
    k_masks = [sa.k_mask_for(s, C) for s in sizes]
    t_shamir = sa.t_for(C)
    for acct, bits in (("paper", costs.PAPER_BITS), ("tpu", costs.TPU_BITS)):
        expect = [costs.round_record(r, model_size, ks, k_masks, C, bits,
                                     n_survivors=C - len(dropped_per_round[r]),
                                     threshold=t_shamir)
                  for r in range(3)]
        t = ledger.totals(acct)
        assert t["upload_bits"] == sum(e.upload_bits for e in expect)
        assert t["download_bits"] == sum(e.download_bits for e in expect)
        assert t["dense_upload_bits"] == sum(e.dense_upload_bits
                                             for e in expect)
        assert t["share_upload_bits"] == sum(e.share_upload_bits
                                             for e in expect)
        assert t["recovery_upload_bits"] == sum(e.recovery_upload_bits
                                                for e in expect)
        assert t["total_upload_bits"] == sum(
            e.upload_bits + e.share_upload_bits + e.recovery_upload_bits
            for e in expect)
        # the reported ratios ARE the Eq. 6-8 quotients, exactly
        assert t["upload_vs_dense"] == (
            sum(e.upload_bits for e in expect)
            / sum(e.dense_upload_bits for e in expect))
        assert t["total_upload_vs_dense"] == (
            t["total_upload_bits"] / t["dense_upload_bits"])
    # the round with a dropped client uploads strictly less gradient but
    # strictly more control traffic (t recovery shares for the dropped key)
    e0, e2 = ledger.entries[0], ledger.entries[2]
    assert e2.n_survivors == C - 1
    assert e2.upload_bits(costs.PAPER_BITS) < e0.upload_bits(costs.PAPER_BITS)
    assert e0.recovery_upload_bits(costs.PAPER_BITS) == 0
    assert e2.recovery_upload_bits(costs.PAPER_BITS) == (
        t_shamir * costs.PAPER_BITS.share_bits())
    assert e2.share_upload_bits(costs.PAPER_BITS) == (
        C * (C - 1) * costs.PAPER_BITS.share_bits())
    # slot facts recorded faithfully
    assert list(e0.ks) == ks and list(e0.k_masks) == k_masks
    assert e0.threshold == t_shamir and e0.secagg
    # what the server logged is what the ledger re-derives
    for rec, e in zip(st.comm_log, ledger.entries):
        assert rec.upload_bits == e.upload_bits(costs.PAPER_BITS)
        assert rec.share_upload_bits == e.share_upload_bits(costs.PAPER_BITS)
        assert rec.recovery_upload_bits == e.recovery_upload_bits(
            costs.PAPER_BITS)


def test_ledger_dense_rounds_and_rejects_factless_records():
    from repro.core.types import CommRecord

    rec = costs.dense_round_record(0, 1000, n_clients=5, n_survivors=4)
    led = CommLedger()
    e = led.record(rec)
    assert not e.sparse
    assert e.upload_bits(costs.PAPER_BITS) == 4 * 1000 * 64
    assert e.dense_upload_bits(costs.PAPER_BITS) == 5 * 1000 * 64
    with pytest.raises(ValueError):
        led.record(CommRecord(round=1, upload_bits=123))


# -------------------------------------------------------------------- engine
_TINY = SimConfig(
    name="tiny", partition="noniid", noniid_k=4, n_clients=5,
    clients_per_round=3, rounds=4, n_train=300, n_test=120,
    local_steps=2, local_batch=8, eval_every=1,
    thgs=THGSConfig(s0=0.1, alpha=0.9, s_min=0.02),
    sa=SecureAggConfig(mask_ratio=0.02), dropout_rate=0.25, seed=3)


def test_engine_end_to_end_writes_ledger_json(tmp_path):
    res = Simulation(_TINY).run()
    assert len(res.ledger) == _TINY.rounds
    assert len(res.accuracies) == _TINY.rounds          # eval_every=1
    assert res.ledger.totals("paper")["compression_x"] > 1.0
    path = res.to_json(str(tmp_path / "ledger.json"))
    data = json.loads(open(path).read())
    assert data["name"] == "tiny"
    assert len(data["ledger"]["entries"]) == _TINY.rounds
    assert (data["ledger"]["paper"]["upload_bits"]
            == res.ledger.totals("paper")["upload_bits"])
    assert data["config"]["thgs"]["s0"] == 0.1


def test_engine_checkpoint_resume_replays_identically(tmp_path):
    # NB: the interrupted leg must run under the SAME rounds horizon — Eq. 2's
    # time-varying factor is (alpha + beta - t/T), so truncating T would
    # change the k schedule, not just stop early.
    ck = str(tmp_path / "ck")
    cfg = _TINY.replace(ckpt_dir=ck, ckpt_every=1)

    class _Killed(Exception):
        pass

    def die_after_round_1(r, info):
        if r == 1:
            raise _Killed

    with pytest.raises(_Killed):
        Simulation(cfg).run(hooks=[die_after_round_1])
    # resume from the round-2 checkpoint and finish
    resumed = Simulation(cfg).run()
    # ...and compare against an uninterrupted run
    full = Simulation(_TINY).run()
    assert [e == f for e, f in zip(resumed.ledger.entries,
                                   full.ledger.entries)] == [True] * 4
    np.testing.assert_allclose(resumed.accuracies, full.accuracies, atol=0)
    np.testing.assert_allclose(resumed.losses, full.losses, rtol=1e-6)


def test_engine_run_twice_is_idempotent():
    sim = Simulation(_TINY.replace(rounds=2))
    r1 = sim.run()
    n1 = len(r1.ledger)
    r2 = sim.run()
    assert len(r2.ledger) == 2 and n1 == 2     # no double-counting
    assert r1.ledger is not r2.ledger          # r1's result stays frozen


def test_engine_resume_skips_orphaned_checkpoint(tmp_path):
    import os

    ck = str(tmp_path / "ck")
    cfg = _TINY.replace(rounds=2, ckpt_dir=ck, ckpt_every=1)
    full = Simulation(cfg).run()
    # simulate a crash between the step-2 npz write and its sidecar write
    os.remove(ck + "/sim_00000002.json")
    resumed = Simulation(cfg).run()            # resumes from step 1
    assert len(resumed.ledger) == 2
    assert resumed.ledger.entries == full.ledger.entries
    np.testing.assert_allclose(resumed.losses, full.losses, rtol=1e-6)


def test_engine_resume_falls_back_past_truncated_sidecar(tmp_path):
    ck = str(tmp_path / "ck")
    cfg = _TINY.replace(rounds=2, ckpt_dir=ck, ckpt_every=1)
    full = Simulation(cfg).run()
    # simulate the pre-atomic-write failure mode: a crash mid-dump left the
    # newest sidecar truncated-but-present; resume must warn, skip it, and
    # fall back to the step-1 pair instead of dying inside json.load
    sidecar = ck + "/sim_00000002.json"
    blob = open(sidecar).read()
    with open(sidecar, "w") as f:
        f.write(blob[: len(blob) // 2])
    with pytest.warns(RuntimeWarning, match="sidecar"):
        resumed = Simulation(cfg).run()        # resumes from step 1
    assert len(resumed.ledger) == 2
    assert resumed.ledger.entries == full.ledger.entries
    np.testing.assert_allclose(resumed.losses, full.losses, rtol=1e-6)


def test_engine_secagg_dropout_ledger_and_band():
    """A dropout run through the secagg_quick preset: ledger totals equal the
    per-round sums INCLUDING share-upload and recovery bits, the Shamir
    threshold bounds every round's survivor count, and the table2 upload-%
    band still holds with recovery traffic counted."""
    cfg = presets.get("secagg_quick").replace(
        rounds=4, n_train=400, n_test=120, eval_every=2, out_json=None)
    res = Simulation(cfg).run()
    entries = res.ledger.entries
    assert len(entries) == 4
    t = cfg.sa.t_for(cfg.clients_per_round)
    assert any(e.n_survivors < e.n_clients for e in entries)  # drops injected
    assert all(e.n_survivors >= t for e in entries)           # recoverable
    assert all(e.threshold == t and e.secagg for e in entries)
    tot = res.ledger.totals("paper")
    per = res.ledger.per_round("paper")
    assert tot["total_upload_bits"] == sum(p["total_upload_bits"]
                                           for p in per)
    assert tot["recovery_upload_bits"] == sum(p["recovery_upload_bits"]
                                              for p in per)
    assert tot["recovery_upload_bits"] > 0
    assert tot["share_upload_bits"] > 0
    # recovery traffic is reported separately from (not folded into) the
    # gradient upload, and the headline band survives counting it
    assert tot["total_upload_bits"] == (
        tot["upload_bits"] + tot["share_upload_bits"]
        + tot["recovery_upload_bits"])
    assert tot["upload_vs_dense"] < tot["total_upload_vs_dense"] < 0.25
    # control plane is a sliver of the data plane
    assert (tot["share_upload_bits"] + tot["recovery_upload_bits"]
            < 0.05 * tot["upload_bits"])


def test_engine_weighted_aggregation_runs():
    cfg = _TINY.replace(rounds=2, sampler="weighted",
                        weight_by_data_count=True, dropout_rate=0.0)
    res = Simulation(cfg).run()
    assert len(res.ledger) == 2 and res.losses[-1] < res.losses[0] * 5


# ------------------------------------------------------------------- presets
def test_presets_validate():
    for name in presets.names():
        cfg = presets.get(name)
        cfg.validate()
        assert cfg.fed().clients_per_round == cfg.clients_per_round
    with pytest.raises(KeyError):
        presets.get("nope")
    assert presets.get("table2_quick").out_json
