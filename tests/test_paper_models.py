"""Paper Table 1: exact parameter counts + forward sanity."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.paper_models import PAPER_MODELS, TABLE1_PARAMS


@pytest.mark.parametrize("name", list(PAPER_MODELS))
def test_table1_param_counts_exact(name):
    m = PAPER_MODELS[name]
    p = m.init(jax.random.key(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(p))
    assert n == TABLE1_PARAMS[name], f"{name}: {n} != {TABLE1_PARAMS[name]}"


@pytest.mark.parametrize("name", list(PAPER_MODELS))
def test_forward_shapes(name):
    m = PAPER_MODELS[name]
    p = m.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, *m.input_shape))
    logits = m.apply(p, x)
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))
