"""Native optimizers vs closed-form updates."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim


def test_sgd_step():
    opt = optim.sgd(0.1)
    p = {"w": jnp.ones(3)}
    g = {"w": jnp.full(3, 2.0)}
    new, _ = opt.step(p, g, opt.init(p))
    np.testing.assert_allclose(np.asarray(new["w"]), 0.8)


def test_momentum_accumulates():
    opt = optim.momentum(0.1, beta=0.5)
    p = {"w": jnp.zeros(1)}
    s = opt.init(p)
    g = {"w": jnp.ones(1)}
    p, s = opt.step(p, g, s)      # m=1, p=-0.1
    p, s = opt.step(p, g, s)      # m=1.5, p=-0.25
    np.testing.assert_allclose(np.asarray(p["w"]), -0.25)


def test_adamw_first_step_is_lr_sized():
    opt = optim.adamw(1e-2, weight_decay=0.0)
    p = {"w": jnp.zeros(4)}
    s = opt.init(p)
    g = {"w": jnp.array([1.0, -1.0, 3.0, -0.5])}
    p2, s2 = opt.step(p, g, s)
    # bias-corrected first step = -lr * sign(g)
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               -1e-2 * np.sign(np.asarray(g["w"])), rtol=1e-4)


def test_adamw_weight_decay():
    opt = optim.adamw(1e-1, weight_decay=0.1)
    p = {"w": jnp.full(2, 10.0)}
    s = opt.init(p)
    g = {"w": jnp.zeros(2)}
    p2, _ = opt.step(p, g, s)
    np.testing.assert_allclose(np.asarray(p2["w"]), 10.0 - 0.1 * 0.1 * 10.0)


def test_adamw_converges_quadratic():
    opt = optim.adamw(0.1)
    p = {"w": jnp.array([5.0, -3.0])}
    s = opt.init(p)
    for _ in range(200):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        p, s = opt.step(p, g, s)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.05
