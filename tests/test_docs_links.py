"""Dead-link check over the markdown docs: every relative link/image target
in the repo-root *.md files must exist in the tree."""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOCS = sorted(ROOT.glob("*.md"))

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def _relative_targets(text):
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP_SCHEMES):
            continue
        yield target.split("#", 1)[0]


@pytest.mark.parametrize("doc", DOCS, ids=[d.name for d in DOCS])
def test_markdown_relative_links_resolve(doc):
    missing = [t for t in _relative_targets(doc.read_text())
               if t and not (doc.parent / t).exists()]
    assert not missing, f"{doc.name} has dead links: {missing}"


def test_docs_exist():
    # the docs the code/docstrings point at must be present
    for name in ("README.md", "EXPERIMENTS.md", "DESIGN.md", "ROADMAP.md"):
        assert (ROOT / name).exists(), name
