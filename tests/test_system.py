"""End-to-end system behaviour: the paper's full FL pipeline on synthetic data.

One compact run stands in for the paper's protocol (§5): 10 clients, Non-IID-4
partition, MNIST-MLP, THGS + sparse-mask secure aggregation — accuracy must
improve over init and the upload compression must beat dense FedAvg.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fedavg import init_state, run_round
from repro.core.types import FedConfig, SecureAggConfig, THGSConfig
from repro.data import MNIST, client_batches, make_dataset, noniid_label_k
from repro.models.paper_models import (MNIST_MLP, accuracy,
                                       cross_entropy_loss)


def test_end_to_end_federated_training():
    x, y = make_dataset(MNIST, 3000, seed=0)
    xt, yt = make_dataset(MNIST, 500, seed=1, train=False)
    parts = noniid_label_k(y, 10, 4, seed=0)

    fed = FedConfig(n_clients=10, clients_per_round=5, local_steps=4,
                    local_batch=32, local_lr=0.05, rounds=12)
    thgs = THGSConfig(s0=0.25, alpha=0.9, s_min=0.05)
    sa = SecureAggConfig(mask_ratio=0.05)

    params = MNIST_MLP.init(jax.random.key(0))
    loss_fn = cross_entropy_loss(MNIST_MLP)
    st = init_state(params, fed)
    acc0 = accuracy(MNIST_MLP, params, xt, yt)

    rs = np.random.RandomState(0)
    for r in range(fed.rounds):
        chosen = rs.choice(fed.n_clients, fed.clients_per_round, replace=False)
        batches = {}
        for c in chosen:
            xb, yb = client_batches(x, y, parts[c], fed.local_batch,
                                    fed.local_steps, seed=r * 100 + c)
            batches[int(c)] = (jnp.asarray(xb), jnp.asarray(yb))
        st = run_round(st, batches, loss_fn, fed, thgs, sa)

    acc1 = accuracy(MNIST_MLP, st.params, xt, yt)
    assert acc1 > acc0 + 0.2, f"no learning: {acc0:.3f} -> {acc1:.3f}"
    # upload compression vs dense FedAvg (Table 2's quantity, single round)
    rec = st.comm_log[-1]
    assert rec.compression > 2.0, f"weak compression {rec.compression:.2f}x"


def test_sparse_fl_tracks_dense_fl():
    """With moderate sparsity the sparse run reaches a loss within 2x of dense."""
    x, y = make_dataset(MNIST, 2000, seed=2)
    parts = noniid_label_k(y, 6, 4, seed=2)
    fed = FedConfig(n_clients=6, clients_per_round=6, local_steps=3,
                    local_batch=32, local_lr=0.05, rounds=8)
    loss_fn = cross_entropy_loss(MNIST_MLP)

    def run(thgs):
        st = init_state(MNIST_MLP.init(jax.random.key(1)), fed)
        for r in range(fed.rounds):
            batches = {}
            for c in range(fed.n_clients):
                xb, yb = client_batches(x, y, parts[c], fed.local_batch,
                                        fed.local_steps, seed=r * 10 + c)
                batches[c] = (jnp.asarray(xb), jnp.asarray(yb))
            st = run_round(st, batches, loss_fn, fed, thgs,
                           SecureAggConfig(enabled=False))
        xa, ya = make_dataset(MNIST, 400, seed=5, train=False)
        return accuracy(MNIST_MLP, st.params, xa, ya)

    acc_dense = run(None)
    acc_sparse = run(THGSConfig(s0=0.3, alpha=0.9, s_min=0.1))
    assert acc_sparse > 0.6 * acc_dense, (acc_dense, acc_sparse)
