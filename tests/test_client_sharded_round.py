"""Client-parallel (device-sharded) round == serial vmap round, bit-exact.

The multi-device runs happen in subprocesses with fake CPU devices
(``--xla_force_host_platform_device_count``) so the main pytest process keeps
seeing exactly 1 device; an in-process variant runs instead when the test
process itself was launched with multiple devices (the CI parity step does
exactly that — see DESIGN.md §11).
"""
import json
import os
import subprocess
import sys

import jax
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Three sims from one config — vmap path, 2-device mesh, all-device mesh —
# must agree bit-exactly: final params, per-round records, accuracies.
SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.core.types import THGSConfig, SecureAggConfig
from repro.launch.mesh import make_clients_mesh
from repro.sim import SimConfig, Simulation

assert len(jax.devices()) == %(ndev)d
base = dict(
    name="parity", model="mnist_mlp", dataset="mnist", rounds=3,
    n_clients=12, clients_per_round=%(cohort)d, n_train=600, n_test=200,
    local_steps=2, local_batch=16, eval_every=1,
    thgs=THGSConfig(s0=0.05, alpha=0.9, s_min=0.01),
    sa=SecureAggConfig(mask_ratio=0.02, seed=3),
    dropout_rate=0.4,           # secagg dropout rounds on the hot path
    weight_by_data_count=True,  # non-uniform client weights
    seed=1,
)

def run(mesh_size):
    sim = Simulation(SimConfig(shard_clients="off", **base))
    if mesh_size:
        sim.mesh = make_clients_mesh(mesh_size)
    res = sim.run(resume=False)
    leaves = jax.tree_util.tree_leaves(sim.state.params)
    return sim, res, leaves

sim0, res0, p0 = run(0)
assert sim0.mesh is None
out = {"accs": [res0.accuracies], "ledgers": [res0.ledger.summary()],
       "bitexact": [], "dropout_rounds": 0}
out["dropout_rounds"] = sum(
    1 for e in res0.ledger.entries if e.n_survivors < e.n_clients)
for ms in %(mesh_sizes)s:
    simS, resS, pS = run(ms)
    out["accs"].append(resS.accuracies)
    out["ledgers"].append(resS.ledger.summary())
    out["bitexact"].append(
        all(bool(jnp.all(a == b)) for a, b in zip(p0, pS)))
print(json.dumps(out))
"""


def _run_snippet(src: str) -> dict:
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, cwd=ROOT, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_round_parity_8dev():
    """1 vs 2 vs 8 host devices: params bit-exact, CommLedger identical."""
    res = _run_snippet(SNIPPET % {
        "ndev": 8, "cohort": 8, "mesh_sizes": "[2, 8]"})
    assert all(res["bitexact"]), res["bitexact"]
    ref = res["ledgers"][0]
    for led in res["ledgers"][1:]:
        assert led == ref
    for accs in res["accs"][1:]:
        assert accs == res["accs"][0]
    # the dropout-recovery path must actually have been exercised
    assert res["dropout_rounds"] >= 1


@pytest.mark.slow
def test_sharded_round_parity_2dev_odd_cohort():
    """2 devices, cohort 6: uneven device/cohort ratios still bit-exact."""
    res = _run_snippet(SNIPPET % {
        "ndev": 2, "cohort": 6, "mesh_sizes": "[2]"})
    assert all(res["bitexact"])
    assert res["ledgers"][1] == res["ledgers"][0]


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a multi-device process (CI runs this file "
                           "under XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")
def test_sharded_round_parity_inprocess():
    """Direct run_round parity when pytest itself has >1 device."""
    import jax.numpy as jnp

    from repro.core import fedavg
    from repro.core.types import FedConfig, SecureAggConfig, THGSConfig
    from repro.launch.mesh import clients_mesh_for

    C, steps, batch = 4, 2, 8
    mesh = clients_mesh_for(C)
    assert mesh is not None

    from repro.models.paper_models import PAPER_MODELS, cross_entropy_loss

    model = PAPER_MODELS["mnist_mlp"]
    loss_fn = cross_entropy_loss(model)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)
    x = jax.random.normal(key, (C, steps, batch, 784))
    y = jax.random.randint(key, (C, steps, batch), 0, 10)
    batches = {c: (x[c], y[c]) for c in range(C)}
    fed = FedConfig(n_clients=C, clients_per_round=C, local_steps=steps,
                    local_batch=batch, local_lr=0.05, rounds=10)
    thgs = THGSConfig(s0=0.05, alpha=0.9, s_min=0.01)
    sa = SecureAggConfig(mask_ratio=0.02, seed=5)
    weights = {c: float(c + 1) for c in range(C)}

    def one_round(mesh_arg, dropped):
        state = fedavg.init_state(params, fed)
        state = fedavg.run_round(state, batches, loss_fn, fed, thgs, sa,
                                 client_weights=weights, dropped=dropped,
                                 mesh=mesh_arg)
        return state

    for dropped in ((), (1,)):
        s_ser = one_round(None, dropped)
        s_sh = one_round(mesh, dropped)
        for a, b in zip(jax.tree_util.tree_leaves(s_ser.params),
                        jax.tree_util.tree_leaves(s_sh.params)):
            assert bool(jnp.all(a == b)), f"params diverge (dropped={dropped})"
        for c in range(C):
            for a, b in zip(
                    jax.tree_util.tree_leaves(s_ser.residuals[c]),
                    jax.tree_util.tree_leaves(s_sh.residuals[c])):
                assert bool(jnp.all(a == b)), f"residuals diverge c={c}"
        assert s_ser.comm_log[-1] == s_sh.comm_log[-1]


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a multi-device process (CI runs this file "
                           "under XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")
def test_sharded_tree_round_parity_inprocess():
    """Hierarchical topology over a sharded cohort: serial-flat ==
    serial-tree == sharded-tree, bit for bit, including a dropout-recovery
    round (DESIGN.md §13 — after the all_gather every device folds the
    identical range-partitioned slot sequence)."""
    import jax.numpy as jnp

    from repro.core import fedavg
    from repro.core.types import FedConfig, SecureAggConfig, THGSConfig
    from repro.launch.mesh import clients_mesh_for

    C, steps, batch = 4, 2, 8
    mesh = clients_mesh_for(C)
    assert mesh is not None

    from repro.models.paper_models import PAPER_MODELS, cross_entropy_loss

    model = PAPER_MODELS["mnist_mlp"]
    loss_fn = cross_entropy_loss(model)
    params = model.init(jax.random.key(0))
    key = jax.random.key(2)
    x = jax.random.normal(key, (C, steps, batch, 784))
    y = jax.random.randint(key, (C, steps, batch), 0, 10)
    batches = {c: (x[c], y[c]) for c in range(C)}
    fed = FedConfig(n_clients=C, clients_per_round=C, local_steps=steps,
                    local_batch=batch, local_lr=0.05, rounds=10)
    thgs = THGSConfig(s0=0.05, alpha=0.9, s_min=0.01)
    sa = SecureAggConfig(mask_ratio=0.02, seed=5)
    weights = {c: float(c + 1) for c in range(C)}

    def one_round(mesh_arg, topology, dropped):
        state = fedavg.init_state(params, fed)
        return fedavg.run_round(state, batches, loss_fn, fed, thgs, sa,
                                client_weights=weights, dropped=dropped,
                                mesh=mesh_arg, topology=topology,
                                tree_groups=3)

    for dropped in ((), (1,)):
        s_flat = one_round(None, "flat", dropped)
        s_tree = one_round(None, "tree", dropped)
        s_shard = one_round(mesh, "tree", dropped)
        for variant, s in (("serial-tree", s_tree), ("sharded-tree", s_shard)):
            for a, b in zip(jax.tree_util.tree_leaves(s_flat.params),
                            jax.tree_util.tree_leaves(s.params)):
                assert bool(jnp.all(a == b)), (
                    f"params diverge: {variant} (dropped={dropped})")
            for c in range(C):
                for a, b in zip(
                        jax.tree_util.tree_leaves(s_flat.residuals[c]),
                        jax.tree_util.tree_leaves(s.residuals[c])):
                    assert bool(jnp.all(a == b)), (
                        f"residuals diverge: {variant} c={c}")
            assert s_flat.comm_log[-1] == s.comm_log[-1]


def test_can_shard_clients_gates():
    """The fallback predicate: 1 device / indivisible cohorts refuse."""
    from repro.core import streams as se
    from repro.launch.mesh import make_clients_mesh

    assert not se.can_shard_clients(None, 8)
    mesh1 = make_clients_mesh(1)
    assert not se.can_shard_clients(mesh1, 8)   # 1 device -> vmap path
    if len(jax.devices()) >= 2:
        mesh2 = make_clients_mesh(2)
        assert se.can_shard_clients(mesh2, 8)
        assert not se.can_shard_clients(mesh2, 7)  # indivisible cohort


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a multi-device process (CI runs this file "
                           "under XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")
@pytest.mark.parametrize("codec", ["int8", "int4", "1bit"])
def test_sharded_codec_parity_inprocess(codec):
    """Quantized-wire sharded rounds are bit-exact with the serial path:
    the packed words themselves are gathered, every device unpacks
    identical bits (DESIGN.md §12)."""
    import numpy as np
    import jax.numpy as jnp

    from repro.core import streams
    from repro.launch.mesh import clients_mesh_for

    C, size, nb, m = 4, 192, 3, 64
    mesh = clients_mesh_for(C)
    assert mesh is not None
    key = jax.random.key(7)
    g = jax.random.normal(key, (C, size), jnp.float32)
    r = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (C, size),
                                jnp.float32)
    sb, nr = streams.encode_leaf_batch(g, r, k=8, nb=nb, m=m, size=size,
                                       codec=codec)
    dense_serial = streams.decode_leaf_batch(sb, nb=nb, m=m, size=size)
    dense_shard, nr_shard = streams.encode_decode_leaf_sharded(
        mesh, g, r, k=8, nb=nb, m=m, size=size, codec=codec)
    np.testing.assert_array_equal(np.asarray(dense_serial),
                                  np.asarray(dense_shard))
    np.testing.assert_array_equal(np.asarray(nr), np.asarray(nr_shard))


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a multi-device process (CI runs this file "
                           "under XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")
def test_sharded_dp_parity_inprocess():
    """DP rounds (common public support + grid noise, DESIGN.md §15) are
    bit-exact between the sharded and serial encodes: every device derives
    the identical support from the round's seed and each shard draws its
    own clients' noise rows."""
    import numpy as np
    import jax.numpy as jnp

    from repro.core import dp, streams
    from repro.launch.mesh import clients_mesh_for

    C, size, nb, m = 4, 192, 3, 64
    mesh = clients_mesh_for(C)
    assert mesh is not None
    key = jax.random.key(11)
    g = jax.random.normal(key, (C, size), jnp.float32)
    r = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (C, size),
                                jnp.float32)
    dpc = dp.DPConfig(clip=1.0, sigma=0.5, seed=11)
    dp_seeds = jnp.asarray(dpc.client_seeds(0, list(range(C))))
    kw = dict(k=8, nb=nb, m=m, size=size, dp_sigma=0.01,
              dp_seeds=dp_seeds, dp_support_seed=dpc.support_seed(0))
    sb, nr = streams.encode_leaf_batch(g, r, **kw)
    dense_serial = streams.decode_leaf_batch(sb, nb=nb, m=m, size=size)
    dense_shard, nr_shard = streams.encode_decode_leaf_sharded(
        mesh, g, r, **kw)
    np.testing.assert_array_equal(np.asarray(dense_serial),
                                  np.asarray(dense_shard))
    np.testing.assert_array_equal(np.asarray(nr), np.asarray(nr_shard))
