"""THGS sparsifier invariants (paper Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; tier-1 must collect without it
from hypothesis import given, settings, strategies as st

from repro.core.sparsify import (densify, first_occurrence_mask, member_of,
                                 sparsify_leaf)
from repro.core.types import THGSConfig

CFG = THGSConfig(s0=0.1, alpha=0.9, s_min=0.01)


@given(n=st.integers(4, 500), k=st.integers(1, 50), seed=st.integers(0, 2**20))
@settings(max_examples=40, deadline=None)
def test_conservation(n, k, seed):
    """sparse + residual == residual_in + grad (error feedback loses nothing)."""
    k = min(k, n)
    key = jax.random.key(seed)
    g = jax.random.normal(key, (n,))
    r = jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 0.3
    out = sparsify_leaf(g, r, k, CFG)
    dense = densify(out.stream, n)
    np.testing.assert_allclose(np.asarray(dense + out.residual),
                               np.asarray(g + r), rtol=1e-5, atol=1e-5)


@given(n=st.integers(4, 500), k=st.integers(1, 50), seed=st.integers(0, 2**20))
@settings(max_examples=40, deadline=None)
def test_topk_selects_largest(n, k, seed):
    k = min(k, n)
    g = jax.random.normal(jax.random.key(seed), (n,))
    out = sparsify_leaf(g, jnp.zeros_like(g), k, CFG)
    sent = np.sort(np.abs(np.asarray(out.stream.values)))
    kept = np.sort(np.abs(np.asarray(out.residual)))[::-1]
    # smallest transmitted magnitude >= largest residual magnitude
    assert sent[0] >= kept[0] - 1e-6


def test_residual_accumulates_over_rounds():
    g = jnp.array([10.0, 0.1, 0.1, 0.1])
    r = jnp.zeros(4)
    for _ in range(3):
        out = sparsify_leaf(g, r, 1, CFG)
        r = out.residual
    # the small coordinates accumulated 3 rounds of 0.1
    np.testing.assert_allclose(np.asarray(r[1:]), 0.3, rtol=1e-5)


@given(seed=st.integers(0, 2**20), n=st.integers(2, 200), dup=st.integers(1, 5))
@settings(max_examples=30, deadline=None)
def test_first_occurrence(seed, n, dup):
    rs = np.random.RandomState(seed)
    idx = jnp.asarray(rs.randint(0, n, size=n * dup), jnp.int32)
    first = np.asarray(first_occurrence_mask(idx))
    seen = set()
    for i, v in enumerate(np.asarray(idx)):
        assert first[i] == (v not in seen)
        seen.add(int(v))


def test_member_of():
    table = jnp.array([5, 1, 9, 1], jnp.int32)
    q = jnp.array([1, 2, 9, 0], jnp.int32)
    assert list(np.asarray(member_of(q, table))) == [True, False, True, False]


def test_sampled_selector_close_to_exact():
    cfg = THGSConfig(s0=0.1, alpha=0.9, s_min=0.01, selector="sampled",
                     sample_frac=0.2)
    g = jax.random.normal(jax.random.key(0), (10_000,))
    out = sparsify_leaf(g, jnp.zeros_like(g), 100, cfg)
    exact = jnp.sort(jnp.abs(g))[-100:]
    got = jnp.sort(jnp.abs(out.stream.values))
    # sampled threshold keeps at least the top half of the true top-k
    overlap = np.intersect1d(np.asarray(exact), np.asarray(got)).size
    assert overlap >= 50
