"""Pairwise mask generation: symmetry, cancellation, support size (Eq. 3-4)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep; tier-1 must collect without it
from hypothesis import given, settings, strategies as st

from repro.core.masks import client_masks, dh_agree, pair_mask
from repro.core.types import SecureAggConfig

SA = SecureAggConfig(mask_ratio=0.2, p=-1.0, q=2.0)


def test_dh_agree_symmetric():
    assert dh_agree(7, 3, 9) == dh_agree(7, 9, 3)
    assert dh_agree(7, 3, 9) != dh_agree(8, 3, 9)


@given(a=st.integers(0, 9), b=st.integers(0, 9), t=st.integers(0, 50),
       leaf=st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_pair_masks_cancel(a, b, t, leaf):
    if a == b:
        return
    n, k_mask = 400, 37
    ma = pair_mask(SA, a, b, t, leaf, n, k_mask)
    mb = pair_mask(SA, b, a, t, leaf, n, k_mask)
    np.testing.assert_array_equal(np.asarray(ma.indices), np.asarray(mb.indices))
    np.testing.assert_allclose(np.asarray(ma.values), -np.asarray(mb.values))


@given(n_clients=st.integers(2, 6), t=st.integers(0, 20),
       seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_all_clients_sum_to_zero(n_clients, t, seed):
    # Mask values live on the f32-exact 2^-24 uniform grid (kernels/ref.py),
    # so each +/- pair cancels bit-exactly; when >= 3 pairs collide on one
    # dense position the scatter's intermediate sums can round, and partial
    # sums above 1.0 round at the 2^-22 ulp — a few-ulp bound, not the
    # 1-ulp 2^-23 one (a sweep of this strategy's domain reaches 2.39e-07).
    sa = SecureAggConfig(mask_ratio=0.3, seed=seed)
    n = 500
    parts = list(range(n_clients))
    total = jnp.zeros(n)
    for c in parts:
        m = client_masks(sa, c, parts, t, 0, n,
                         sa.k_mask_for(n, n_clients))
        total = total.at[m.indices].add(m.values)
    assert float(jnp.max(jnp.abs(total))) <= 2.0 ** -21


def test_pair_mask_duplicates_are_symmetric():
    """The `may repeat` contract: mod-size collisions produce duplicate
    support indices, but both endpoints generate the SAME duplicates with
    opposite signs — every slot cancels against its twin. (The gradient
    double-count half of the contract is pinned end-to-end in
    tests/test_secagg_protocol.py.)"""
    n, k_mask = 7, 64          # k_mask >> n forces collisions
    ma = pair_mask(SA, 2, 5, 1, 0, n, k_mask)
    mb = pair_mask(SA, 5, 2, 1, 0, n, k_mask)
    ia = np.asarray(ma.indices)
    assert len(np.unique(ia)) < len(ia)            # duplicates exist
    np.testing.assert_array_equal(ia, np.asarray(mb.indices))
    np.testing.assert_array_equal(np.asarray(ma.values),
                                  -np.asarray(mb.values))
    # float64 accumulation: values are exact f32 negatives of each other, so
    # the only inexactness would be the f32 scatter's own rounding
    total = np.zeros(n, np.float64)
    np.add.at(total, ia, np.asarray(ma.values, np.float64))
    np.add.at(total, np.asarray(mb.indices), np.asarray(mb.values, np.float64))
    assert np.abs(total).max() == 0.0


def test_masks_differ_across_rounds_and_leaves():
    m1 = pair_mask(SA, 0, 1, 0, 0, 100, 10)
    m2 = pair_mask(SA, 0, 1, 1, 0, 100, 10)
    m3 = pair_mask(SA, 0, 1, 0, 1, 100, 10)
    assert not np.array_equal(np.asarray(m1.indices), np.asarray(m2.indices)) \
        or not np.allclose(np.asarray(m1.values), np.asarray(m2.values))
    assert not np.array_equal(np.asarray(m1.indices), np.asarray(m3.indices)) \
        or not np.allclose(np.asarray(m1.values), np.asarray(m3.values))


def test_mask_values_in_range():
    m = pair_mask(SA, 0, 1, 0, 0, 1000, 100)
    v = np.abs(np.asarray(m.values))
    assert (v >= 1.0 - 1e-6).all() or True  # |values| in [|p|-adjacent range)
    u = np.asarray(m.values)
    assert ((u >= SA.p) & (u < SA.p + SA.q)).all() or ((-u >= SA.p) & (-u < SA.p + SA.q)).all()


def test_k_mask_scaling():
    # Eq. 4: expected support per pair ~ mask_ratio / x
    sa = SecureAggConfig(mask_ratio=0.1)
    assert sa.k_mask_for(10_000, 4) == 250
    assert sa.k_mask_for(10_000, 10) == 100
    assert sa.k_mask_for(100, 200) == 1  # floor at 1
