"""Hierarchical (tree) aggregation == flat aggregation, bit-exact (§13).

The tree decode partitions the padded dense buffer into contiguous index
ranges — one sub-aggregator per range — and combines partials by pure
concatenation, so for ANY partition every output position folds the exact
same contributions in the exact same order as the flat fused scatter-add.
This suite pins that property where it could silently break:

  * arbitrary uneven partitions (group sizes 1..n, randomly drawn cuts);
  * secure aggregation with arbitrary survivor subsets >= the Shamir
    threshold (Bonawitz dropout recovery streams route by range too);
  * every wire codec (the codec round trip happens at encode; decode is
    codec-agnostic);
  * the full round: run_round(topology='tree') vs 'flat' — params,
    residuals and the CommLedger facts identical.

The partition/dropout parity properties run as hypothesis property tests
when hypothesis is installed, and fall back to a seeded deterministic sweep
over the same case space otherwise (tier-1 containers ship without it).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # dev-only dep; the seeded sweep below keeps coverage without it
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

from repro.core import streams as se
from repro.core.fedavg import init_state, run_round
from repro.core.types import FedConfig, SecureAggConfig, THGSConfig
from repro.secagg import RoundProtocol

THGS = THGSConfig(s0=0.2, alpha=0.9, s_min=0.05, time_varying=False)


def _random_splits(rng, padded: int) -> tuple:
    """Arbitrary monotone boundaries (0, ..., padded): uneven group sizes,
    including width-1 ranges."""
    n_cuts = int(rng.integers(0, min(5, padded - 1) + 1))
    cuts = rng.choice(np.arange(1, padded), size=n_cuts, replace=False)
    return (0, *sorted(int(c) for c in cuts), padded)


# --------------------------------------------------- parity case generators
def _check_partition_case(C, nb, m, k, seed, splits):
    """Core property: weighted tree decode == flat decode, bit for bit —
    any partition, any C/nb/k, negative values, duplicate indices (the
    -0.0 dump-slot property rides on this)."""
    key = jax.random.key(seed)
    idx = jax.random.randint(key, (C, nb, k), 0, nb * m, dtype=jnp.int32)
    vals = jax.random.normal(jax.random.fold_in(key, 1), (C, nb, k))
    weights = jax.random.uniform(jax.random.fold_in(key, 2), (C,),
                                 minval=0.1, maxval=3.0)
    stb = se.StreamBatch(indices=idx, values=vals)
    flat = se.decode_sum_blocks(stb, nb, m, weights=weights)
    tree = se.decode_sum_tree(stb, nb, m, splits=splits, weights=weights)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(tree))


def _check_secagg_dropout_case(C, size, k, seed, mask_ratio, survivors,
                               splits):
    """Core property: masked round, survivor subset >= Shamir t — the
    Bonawitz recovery streams join the round stream before range routing,
    so the tree decode cancels masks exactly like flat."""
    sa = SecureAggConfig(mask_ratio=mask_ratio, threshold=0.6, seed=seed)
    participants = list(range(C))
    proto = RoundProtocol.setup(sa, participants, round_t=0)
    pair_seeds, pair_signs = proto.pair_seed_matrix()
    k_mask = sa.k_mask_for(size, C)
    key = jax.random.key(seed)
    grads = jax.random.normal(key, (C, size))
    residuals = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (C, size))
    weights = jax.random.uniform(jax.random.fold_in(key, 2), (C,),
                                 minval=0.5, maxval=2.0)
    stb, _ = se.encode_leaf_batch(
        grads, residuals, k=k, nb=1, m=size, size=size,
        pair_seeds=pair_seeds, pair_signs=pair_signs, k_mask=k_mask,
        mask_p=sa.p, mask_q=sa.q, leaf_id=0, weights=weights)

    assert len(survivors) >= proto.t
    dropped = sorted(set(participants) - set(survivors))
    alive = jnp.asarray([c in survivors for c in participants], bool)
    rec_seeds = (proto.recover_seeds(sorted(survivors), dropped) if dropped
                 else pair_seeds)
    flat = se.decode_leaf_batch(
        stb, nb=1, m=size, size=size, alive=alive, pair_seeds=rec_seeds,
        pair_signs=pair_signs, k_mask=k_mask, mask_p=sa.p, mask_q=sa.q,
        leaf_id=0)
    tree = se.decode_leaf_tree(
        stb, nb=1, m=size, size=size, splits=splits, alive=alive,
        pair_seeds=rec_seeds, pair_signs=pair_signs, k_mask=k_mask,
        mask_p=sa.p, mask_q=sa.q, leaf_id=0)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(tree))


# ------------------------------------------------------------ decode parity
def test_tree_splits_shapes():
    assert se.tree_splits(10, 3) == (0, 4, 7, 10)
    assert se.tree_splits(10, 1) == (0, 10)
    assert se.tree_splits(4, 9) == (0, 1, 2, 3, 4)   # clamped to padded
    assert se.tree_splits(7, 0) == (0, 7)            # clamped to >= 1
    with pytest.raises(ValueError):
        se.decode_sum_tree(
            se.StreamBatch(indices=jnp.zeros((1, 1, 1), jnp.int32),
                           values=jnp.zeros((1, 1, 1), jnp.float32)),
            1, 8, splits=(0, 9))                     # boundary != padded


@pytest.mark.parametrize("case_seed", range(12))
def test_tree_decode_bitexact_partition_sweep(case_seed):
    """Seeded sweep over the partition-parity case space (always runs; the
    hypothesis twin below explores it adversarially when available)."""
    rng = np.random.default_rng([0xBEEF, case_seed])
    C = int(rng.integers(1, 6))
    nb = int(rng.integers(1, 4))
    m = int(rng.integers(2, 25))
    k = int(rng.integers(1, 2 * m + 1))
    splits = _random_splits(rng, nb * m)
    _check_partition_case(C, nb, m, k, int(rng.integers(0, 2**31)), splits)


@pytest.mark.parametrize("case_seed", range(8))
def test_tree_decode_bitexact_secagg_dropout_sweep(case_seed):
    """Seeded sweep over the secagg-dropout parity case space."""
    rng = np.random.default_rng([0xD00D, case_seed])
    C = int(rng.integers(2, 7))
    size = int(rng.integers(8, 97))
    k = int(rng.integers(1, size + 1))
    mask_ratio = float(rng.choice([0.05, 0.2]))
    sa = SecureAggConfig(mask_ratio=mask_ratio, threshold=0.6)
    t = sa.t_for(C)
    n_surv = int(rng.integers(t, C + 1))
    survivors = sorted(int(c) for c in
                       rng.choice(C, size=n_surv, replace=False))
    splits = _random_splits(rng, size)
    _check_secagg_dropout_case(C, size, k, int(rng.integers(0, 1000)),
                               mask_ratio, survivors, splits)


if st is not None:
    def _draw_splits(data, padded: int) -> tuple:
        n_cuts = data.draw(st.integers(0, min(5, padded - 1)), label="n_cuts")
        cuts = data.draw(
            st.lists(st.integers(1, padded - 1), min_size=n_cuts,
                     max_size=n_cuts, unique=True), label="cuts")
        return (0, *sorted(cuts), padded)

    @given(data=st.data())
    @settings(max_examples=20, deadline=None)
    def test_tree_decode_bitexact_arbitrary_partitions(data):
        C = data.draw(st.integers(1, 5), label="C")
        nb = data.draw(st.integers(1, 3), label="nb")
        m = data.draw(st.integers(2, 24), label="m")
        k = data.draw(st.integers(1, 2 * m), label="k")
        seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
        splits = _draw_splits(data, nb * m)
        _check_partition_case(C, nb, m, k, seed, splits)

    @given(data=st.data())
    @settings(max_examples=15, deadline=None)
    def test_tree_decode_bitexact_secagg_dropout(data):
        C = data.draw(st.integers(2, 6), label="C")
        size = data.draw(st.integers(8, 96), label="size")
        k = data.draw(st.integers(1, size), label="k")
        seed = data.draw(st.integers(0, 1000), label="seed")
        ratio = data.draw(st.sampled_from([0.05, 0.2]), label="ratio")
        t = SecureAggConfig(mask_ratio=ratio, threshold=0.6).t_for(C)
        n_surv = data.draw(st.integers(t, C), label="n_surv")
        survivors = sorted(
            data.draw(st.permutations(list(range(C))),
                      label="perm")[:n_surv])
        splits = _draw_splits(data, size)
        _check_secagg_dropout_case(C, size, k, seed, ratio, survivors,
                                   splits)


@pytest.mark.parametrize("codec", ["f32", "int8", "int4", "1bit"])
def test_tree_decode_bitexact_all_codecs(codec):
    """The codec round trip happens at encode (quantize -> pack -> unpack ->
    dequantize); the decode sees f32 streams, so tree == flat holds per
    codec too."""
    C, size, k = 4, 192, 8
    key = jax.random.key(3)
    grads = jax.random.normal(key, (C, size))
    residuals = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (C, size))
    stb, _ = se.encode_leaf_batch(
        grads, residuals, k=k, nb=1, m=size, size=size, codec=codec)
    flat = se.decode_leaf_batch(stb, nb=1, m=size, size=size)
    for splits in [(0, size), (0, 1, size), (0, 7, 61, 62, size),
                   se.tree_splits(size, 13)]:
        tree = se.decode_leaf_tree(stb, nb=1, m=size, size=size,
                                   splits=splits)
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(tree))


# ------------------------------------------------------------- round parity
def _one_round(topology, tree_groups, dropped):
    from repro.models.paper_models import PAPER_MODELS, cross_entropy_loss

    C, steps, batch = 5, 2, 8
    model = PAPER_MODELS["mnist_mlp"]
    loss_fn = cross_entropy_loss(model)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)
    x = jax.random.normal(key, (C, steps, batch, 784))
    y = jax.random.randint(key, (C, steps, batch), 0, 10)
    batches = {c: (x[c], y[c]) for c in range(C)}
    fed = FedConfig(n_clients=C, clients_per_round=C, local_steps=steps,
                    local_batch=batch, local_lr=0.05, rounds=10)
    sa = SecureAggConfig(mask_ratio=0.02, threshold=0.6, seed=5)
    weights = {c: float(c + 1) for c in range(C)}
    state = init_state(params, fed)
    return run_round(state, batches, loss_fn, fed, THGS, sa,
                     client_weights=weights, dropped=dropped,
                     topology=topology, tree_groups=tree_groups)


@pytest.mark.parametrize("dropped", [(), (1, 3)])
@pytest.mark.parametrize("tree_groups", [0, 1, 3])
def test_run_round_tree_equals_flat(dropped, tree_groups):
    """Full secagg round: params, residuals and the CommRecord facts are
    bit-identical across topologies (with and without dropout recovery)."""
    s_flat = _one_round("flat", 0, dropped)
    s_tree = _one_round("tree", tree_groups, dropped)
    for a, b in zip(jax.tree_util.tree_leaves(s_flat.params),
                    jax.tree_util.tree_leaves(s_tree.params)):
        assert bool(jnp.all(a == b)), f"params diverge (dropped={dropped})"
    for c in s_flat.residuals:
        for a, b in zip(jax.tree_util.tree_leaves(s_flat.residuals[c]),
                        jax.tree_util.tree_leaves(s_tree.residuals[c])):
            assert bool(jnp.all(a == b)), f"residuals diverge c={c}"
    assert s_flat.comm_log[-1] == s_tree.comm_log[-1]


def test_ledger_totals_identical_across_topologies():
    """CommLedger stays exact under the tree: same round facts -> identical
    totals under BOTH accountings (the topology never touches the wire
    accounting — clients upload the same streams either way)."""
    from repro.sim.ledger import CommLedger

    led_flat, led_tree = CommLedger(), CommLedger()
    for dropped in ((), (1, 3)):
        led_flat.record(_one_round("flat", 0, dropped).comm_log[-1])
        led_tree.record(_one_round("tree", 3, dropped).comm_log[-1])
    for acct in ("paper", "tpu"):
        assert led_flat.totals(acct) == led_tree.totals(acct)
    assert led_flat.summary() == led_tree.summary()


def test_tree_requires_thgs_and_valid_topology():
    from repro.models.paper_models import PAPER_MODELS, cross_entropy_loss

    model = PAPER_MODELS["mnist_mlp"]
    loss_fn = cross_entropy_loss(model)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)
    batches = {0: (jax.random.normal(key, (1, 4, 784)),
                   jax.random.randint(key, (1, 4), 0, 10))}
    fed = FedConfig(n_clients=1, clients_per_round=1, local_steps=1,
                    local_batch=4, local_lr=0.05, rounds=4)
    state = init_state(params, fed)
    sa = SecureAggConfig(enabled=False)
    with pytest.raises(ValueError, match="requires THGS"):
        run_round(state, batches, loss_fn, fed, None, sa, topology="tree")
    with pytest.raises(ValueError, match="unknown topology"):
        run_round(state, batches, loss_fn, fed, THGS, sa, topology="ring")
