"""Block-local THGS encode (the datacenter-mesh path, core/blocked.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep; tier-1 must collect without it
from hypothesis import given, settings, strategies as st

from repro.core.blocked import (block_layout, decode_blocked_sum,
                                encode_leaf_blocked)


@given(size=st.integers(10, 5000), n_blocks=st.sampled_from([1, 2, 4, 8]),
       seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_blocked_conservation(size, n_blocks, seed):
    key = jax.random.key(seed)
    g = jax.random.normal(key, (size,))
    r = jnp.zeros_like(g)
    nb, m, _ = block_layout(size, n_blocks)
    stream, new_r = encode_leaf_blocked(g, r, k_block=3, n_blocks=n_blocks)
    dense = decode_blocked_sum(stream.indices[None], stream.values[None],
                               size, n_blocks, weight=1.0)
    np.testing.assert_allclose(np.asarray(dense + new_r.reshape(-1)),
                               np.asarray(g), rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**16), n_fed=st.integers(2, 4))
@settings(max_examples=15, deadline=None)
def test_blocked_masks_cancel(seed, n_fed):
    """Sum over participants of masked streams == sum of unmasked sparse parts."""
    key = jax.random.key(seed)
    size, nb, kb, km = 600, 4, 5, 7
    mask_key = jax.random.fold_in(key, 999)
    idx_all, val_all, expected = [], [], jnp.zeros(size)
    for me in range(n_fed):
        g = jax.random.normal(jax.random.fold_in(key, me), (size,))
        stream, new_r = encode_leaf_blocked(
            g, jnp.zeros_like(g), kb, nb, mask_key=mask_key,
            k_mask_block=km, n_peers=n_fed, self_id=jnp.int32(me))
        idx_all.append(stream.indices)
        val_all.append(stream.values)
        expected = expected + (g - new_r.reshape(-1))
    dense = decode_blocked_sum(jnp.stack(idx_all), jnp.stack(val_all),
                               size, nb, weight=1.0)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(expected),
                               rtol=1e-4, atol=1e-4)


def test_small_leaf_collapses_to_one_block():
    nb, m, padded = block_layout(10, 8)
    assert nb == 1 and m == 10
