"""FedBuff-style async mode (DESIGN.md §13): buffered staleness-weighted
updates, counter-based simulated staleness, and bit-identical
checkpoint/resume of the parameter-version ring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedavg import (init_state, run_async_update, run_round,
                               staleness_weight)
from repro.core.types import FedConfig, SecureAggConfig, THGSConfig
from repro.sim import AsyncSimulation, SimConfig, Simulation, presets
from repro.sim.engine import simulate

THGS = THGSConfig(s0=0.2, alpha=0.9, s_min=0.05, time_varying=False)

_ASYNC = SimConfig(
    name="async_tiny", partition="noniid", noniid_k=4, n_clients=6,
    clients_per_round=3, rounds=5, n_train=300, n_test=120,
    local_steps=2, local_batch=8, eval_every=1, thgs=THGS,
    sa=SecureAggConfig(enabled=False), mode="async", buffer_size=3,
    max_staleness=2, seed=9)


# ---------------------------------------------------------- update semantics
def _setup(C=4, steps=2, batch=8):
    from repro.models.paper_models import PAPER_MODELS, cross_entropy_loss

    model = PAPER_MODELS["mnist_mlp"]
    loss_fn = cross_entropy_loss(model)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)
    x = jax.random.normal(key, (C, steps, batch, 784))
    y = jax.random.randint(key, (C, steps, batch), 0, 10)
    batches = {c: (x[c], y[c]) for c in range(C)}
    fed = FedConfig(n_clients=C, clients_per_round=C, local_steps=steps,
                    local_batch=batch, local_lr=0.05, rounds=10)
    return loss_fn, params, batches, fed


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def test_staleness_weight_values():
    assert staleness_weight(0) == 1.0
    assert staleness_weight(3) == pytest.approx(0.5)
    ws = [staleness_weight(t) for t in range(6)]
    assert ws == sorted(ws, reverse=True) and all(w > 0 for w in ws)


def test_all_fresh_buffer_is_the_sync_round():
    """tau == 0 everywhere -> run_async_update IS run_round, bit for bit:
    params, residuals and losses identical (the async path only adds the
    staleness machinery, never a different code path for weight 1)."""
    loss_fn, params, batches, fed = _setup()
    weights = {c: float(c + 1) for c in batches}
    sa_off = SecureAggConfig(enabled=False)

    s_sync = run_round(init_state(params, fed), batches, loss_fn, fed,
                       THGS, sa_off, client_weights=weights)
    s_async = run_async_update(
        init_state(params, fed), batches,
        {c: params for c in batches}, loss_fn, fed, THGS,
        client_weights=weights)
    assert _trees_equal(s_sync.params, s_async.params)
    for c in batches:
        assert _trees_equal(s_sync.residuals[c], s_async.residuals[c])
    assert s_sync.losses == s_async.losses
    # the records agree on every shared fact; async additionally logs taus
    r_s, r_a = s_sync.comm_log[-1], s_async.comm_log[-1]
    assert (r_s.ks, r_s.model_size, r_s.n_clients) == (
        r_a.ks, r_a.model_size, r_a.n_clients)
    assert r_a.staleness == (0,) * len(batches)
    assert r_s.staleness == ()


def test_staleness_is_exactly_a_multiplicative_weight():
    """A report at staleness tau aggregates identically to a fresh report
    whose client weight was pre-multiplied by (1 + tau)^-0.5 — staleness
    enters the data plane through the weight vector and nowhere else."""
    loss_fn, params, batches, fed = _setup()
    # give the 'stale' clients genuinely stale params so the deltas differ
    older = jax.tree_util.tree_map(lambda x: x * 0.9, params)
    client_params = {0: params, 1: older, 2: older, 3: params}
    taus = {0: 0, 1: 2, 2: 1, 3: 0}
    base_w = {c: float(c + 1) for c in batches}

    s_tau = run_async_update(
        init_state(params, fed), batches, client_params, loss_fn, fed, THGS,
        staleness=taus, client_weights=base_w)
    folded = {c: base_w[c] * staleness_weight(taus[c]) for c in batches}
    s_folded = run_async_update(
        init_state(params, fed), batches, client_params, loss_fn, fed, THGS,
        client_weights=folded)
    assert _trees_equal(s_tau.params, s_folded.params)
    for c in batches:
        assert _trees_equal(s_tau.residuals[c], s_folded.residuals[c])
    assert s_tau.comm_log[-1].staleness == (0, 2, 1, 0)  # sorted participants


def test_async_update_tree_topology_matches_flat():
    loss_fn, params, batches, fed = _setup()
    older = jax.tree_util.tree_map(lambda x: x * 0.95, params)
    client_params = {c: (older if c % 2 else params) for c in batches}
    taus = {c: c % 3 for c in batches}
    s_flat = run_async_update(init_state(params, fed), batches, client_params,
                              loss_fn, fed, THGS, staleness=taus)
    s_tree = run_async_update(init_state(params, fed), batches, client_params,
                              loss_fn, fed, THGS, staleness=taus,
                              topology="tree", tree_groups=3)
    assert _trees_equal(s_flat.params, s_tree.params)
    assert s_flat.comm_log[-1] == s_tree.comm_log[-1]


def test_async_update_rejections():
    loss_fn, params, batches, fed = _setup(C=2)
    with pytest.raises(ValueError, match="requires THGS"):
        run_async_update(init_state(params, fed), batches,
                         {c: params for c in batches}, loss_fn, fed, None)
    with pytest.raises(ValueError, match="unknown topology"):
        run_async_update(init_state(params, fed), batches,
                         {c: params for c in batches}, loss_fn, fed, THGS,
                         topology="star")


# ------------------------------------------------------------------- engine
def test_async_engine_staleness_facts_are_counter_based():
    """Every ledger entry's staleness taus replay from the documented
    counter-based draw (seed, 0xA5, t) with hi = min(t, ring-1, max) — a
    pure function of the round index, independent of execution history."""
    cfg = _ASYNC
    res = AsyncSimulation(cfg).run()
    assert len(res.ledger) == cfg.rounds
    for t, e in enumerate(res.ledger.entries):
        hi = min(t, cfg.max_staleness)   # ring has min(t+1, max+1) versions
        rng = np.random.default_rng([cfg.seed, 0xA5, t])
        expect = tuple(int(x) for x in
                       rng.integers(0, hi + 1, size=cfg.buffer_size))
        assert e.staleness == expect
        assert all(0 <= tau <= hi for tau in e.staleness)
        assert e.n_clients == e.n_survivors == cfg.buffer_size
    # round 0 has no older version to be stale against
    assert res.ledger.entries[0].staleness == (0,) * cfg.buffer_size
    # with max_staleness=2 and 5 rounds some report is actually stale
    assert any(tau > 0 for e in res.ledger.entries for tau in e.staleness)


def test_async_engine_checkpoint_resume_bit_identical(tmp_path):
    """Interrupt mid-run, resume from the checkpointed parameter-version
    ring: ledger entries (incl. staleness facts) and final params are
    bit-identical with the uninterrupted run."""
    ck = str(tmp_path / "ck")
    cfg = _ASYNC.replace(ckpt_dir=ck, ckpt_every=1)

    class _Killed(Exception):
        pass

    def die_after_round_1(r, info):
        if r == 1:
            raise _Killed

    with pytest.raises(_Killed):
        AsyncSimulation(cfg).run(hooks=[die_after_round_1])
    resumed_sim = AsyncSimulation(cfg)
    resumed = resumed_sim.run()
    full_sim = AsyncSimulation(_ASYNC)
    full = full_sim.run()
    assert resumed.ledger.entries == full.ledger.entries
    assert [e.staleness for e in resumed.ledger.entries] == [
        e.staleness for e in full.ledger.entries]
    assert _trees_equal(resumed_sim.state.params, full_sim.state.params)
    for v_r, v_f in zip(resumed_sim.versions, full_sim.versions):
        assert _trees_equal(v_r, v_f)
    np.testing.assert_array_equal(resumed.losses, full.losses)
    np.testing.assert_array_equal(resumed.accuracies, full.accuracies)


def test_async_engine_ledger_json_carries_staleness(tmp_path):
    import json

    res = AsyncSimulation(_ASYNC.replace(rounds=3)).run()
    path = res.to_json(str(tmp_path / "ledger.json"))
    data = json.loads(open(path).read())
    entries = data["ledger"]["entries"]
    assert len(entries) == 3
    assert all(len(e["staleness"]) == _ASYNC.buffer_size for e in entries)
    assert data["ledger"]["paper"]["upload_bits"] > 0
    # round-trip: from_entry_dicts restores the staleness fact (resume path)
    from repro.sim.ledger import CommLedger

    led = CommLedger.from_entry_dicts(entries)
    assert [e.staleness for e in led.entries] == [
        tuple(e["staleness"]) for e in entries]


# ------------------------------------------------------- config + routing
def test_simulate_routes_by_mode():
    r = simulate(_ASYNC.replace(rounds=2))
    assert len(r.ledger) == 2 and r.ledger.entries[0].staleness
    with pytest.raises(ValueError, match="mode='async'"):
        Simulation(_ASYNC)
    with pytest.raises(ValueError, match="mode='sync'"):
        AsyncSimulation(_ASYNC.replace(mode="sync", buffer_size=0))


def test_async_config_validation():
    with pytest.raises(ValueError, match="requires THGS"):
        _ASYNC.replace(thgs=None, codec="f32").validate()
    with pytest.raises(ValueError, match="secure aggregation"):
        _ASYNC.replace(sa=SecureAggConfig(mask_ratio=0.05)).validate()
    with pytest.raises(ValueError, match="no dropout"):
        _ASYNC.replace(dropout_rate=0.2).validate()
    with pytest.raises(ValueError, match="buffer_size"):
        _ASYNC.replace(buffer_size=100).validate()
    with pytest.raises(ValueError, match="max_staleness"):
        _ASYNC.replace(max_staleness=-1).validate()
    with pytest.raises(ValueError, match="serial update path"):
        _ASYNC.replace(shard_clients="on").validate()
    with pytest.raises(ValueError, match="only meaningful"):
        _ASYNC.replace(mode="sync").validate()   # buffer_size=3 left set
    with pytest.raises(ValueError, match="topology"):
        _ASYNC.replace(topology="ring").validate()
    _ASYNC.validate()                            # the base config is legal


def test_async_preset_runs():
    cfg = presets.get("async_quick")
    cfg.validate()
    assert cfg.mode == "async" and cfg.thgs is not None
    cfg = presets.get("tree_quick")
    cfg.validate()
    assert cfg.topology == "tree"
