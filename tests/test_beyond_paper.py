"""Beyond-paper optimizations: int8 KV cache, sharding-aligned block view."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.blocked import sharding_aligned_transform
from repro.models import transformer as tf

KEY = jax.random.key(0)


@pytest.mark.parametrize("arch", ["yi_6b", "granite_20b"])
def test_int8_kv_cache_close_to_bf16(arch):
    cfg = configs.reduced(configs.get(arch))
    cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
    params = tf.init_params(cfg, KEY)
    tok = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 1), 0, cfg.vocab)

    def run(c):
        st = tf.init_decode_state(c, 2, 32)
        lg = None
        for i in range(4):
            lg, st = tf.decode_step(params, c, tok + i, st)
        return lg

    lg_f, lg_q = run(cfg), run(cfg8)
    # int8 storage: same argmax behaviour, logits close
    assert jnp.allclose(lg_f, lg_q, rtol=0.2, atol=0.5), (
        float(jnp.max(jnp.abs(lg_f - lg_q))))


def test_int8_state_dtype():
    cfg = dataclasses.replace(configs.reduced(configs.get("yi_6b")),
                              kv_dtype="int8")
    st = tf.init_decode_state(cfg, 2, 16)
    assert any(x.dtype == jnp.int8
               for x in jax.tree_util.tree_leaves(st.caches))


@pytest.mark.parametrize("shape,spec,axis_sizes,expected_nb", [
    ((64, 32), ("data", "model"), {"data": 4, "model": 2}, 8),
    ((3, 64, 32), (None, "data", "model"), {"data": 4, "model": 2}, 8),
    ((64, 32), (None, "model"), {"data": 4, "model": 2}, 2),
    ((16,), (None,), {"data": 4, "model": 2}, None),   # replicated -> None
])
def test_sharding_aligned_transform_roundtrip(shape, spec, axis_sizes,
                                              expected_nb):
    from jax.sharding import PartitionSpec as P

    tr = sharding_aligned_transform(shape, P(*spec), axis_sizes,
                                    ("data", "model"))
    if expected_nb is None:
        assert tr is None
        return
    to_b, from_b, nb, m, front = tr
    assert nb == expected_nb
    x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
    b = to_b(x)
    assert b.shape == (nb, m)
    np.testing.assert_array_equal(np.asarray(from_b(b)), np.asarray(x))
