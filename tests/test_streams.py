"""The unified batched stream engine (core/streams.py): exactness, weighting,
dropout recovery, and equivalence with the protocol-reference encode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import streams
from repro.core.masks import client_masks, pair_mask
from repro.core.secure_agg import encode_leaf
from repro.core.types import SecureAggConfig, THGSConfig

THGS = THGSConfig(s0=0.2, alpha=0.9, s_min=0.05)


def _batch(key, C, n):
    g = jax.random.normal(key, (C, n))
    return g, jnp.zeros_like(g)


@pytest.mark.parametrize("C,n,k", [(2, 300, 10), (5, 1000, 25)])
def test_batched_encode_decode_exact_no_masks(C, n, k):
    g, r = _batch(jax.random.key(0), C, n)
    st, nr = streams.encode_leaf_batch(g, r, k=k, nb=1, m=n, size=n)
    dense = streams.decode_leaf_batch(st, nb=1, m=n, size=n)
    np.testing.assert_allclose(np.asarray(dense), np.asarray((g - nr).sum(0)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("path", ["seeds", "keys"])
@pytest.mark.parametrize("seed,C", [(0, 2), (1, 3), (2, 5)])
def test_batched_masks_cancel(seed, C, path):
    """Sum of masked streams over all clients == sum of unmasked sparse parts,
    on both mask data planes (counter-based seeds = the secagg protocol path;
    jax.random keys = the legacy fold-key path)."""
    n, k = 600, 12
    sa = SecureAggConfig(mask_ratio=0.3, seed=seed)
    g, r = _batch(jax.random.key(seed), C, n)
    km = sa.k_mask_for(n, C)
    kw = {}
    if path == "seeds":
        kw["pair_seeds"], kw["pair_signs"] = streams.pair_seed_matrix(
            sa, list(range(C)), round_t=3)
    else:
        kw["pair_keys"], kw["pair_signs"] = streams.pair_key_matrix(
            sa, list(range(C)), round_t=3)
    st, nr = streams.encode_leaf_batch(
        g, r, k=k, nb=1, m=n, size=n, k_mask=km, mask_p=sa.p, mask_q=sa.q,
        leaf_id=0, **kw)
    dense = streams.decode_leaf_batch(st, nb=1, m=n, size=n)
    np.testing.assert_allclose(np.asarray(dense), np.asarray((g - nr).sum(0)),
                               rtol=1e-4, atol=1e-5)


def test_weighted_aggregation_exact_under_masks():
    """Client-side weights scale the gradient part only, so non-uniform
    weighted aggregation still cancels the pairwise masks exactly."""
    C, n, k = 4, 800, 15
    sa = SecureAggConfig(mask_ratio=0.2, seed=11)
    g, r = _batch(jax.random.key(4), C, n)
    w = jnp.array([0.4, 0.3, 0.2, 0.1])
    pk, ps = streams.pair_seed_matrix(sa, list(range(C)), round_t=0)
    km = sa.k_mask_for(n, C)
    st, nr = streams.encode_leaf_batch(
        g, r, k=k, nb=1, m=n, size=n, pair_seeds=pk, pair_signs=ps,
        k_mask=km, mask_p=sa.p, mask_q=sa.q, leaf_id=0, weights=w)
    dense = streams.decode_leaf_batch(st, nb=1, m=n, size=n)
    expected = ((g - nr) * w[:, None]).sum(0)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(expected),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("drop", [[2], [0, 3]])
def test_dropout_mask_reconstruction_cancels(drop):
    """Sum over survivors with reconstructed pair masks == unmasked sparse sum
    over survivors (Bonawitz recovery); without reconstruction it is wrong."""
    C, n, k = 4, 700, 10
    sa = SecureAggConfig(mask_ratio=0.3, seed=5)
    g, r = _batch(jax.random.key(9), C, n)
    alive = jnp.array([c not in drop for c in range(C)])
    pk, ps = streams.pair_seed_matrix(sa, list(range(C)), round_t=2)
    km = sa.k_mask_for(n, C)
    st, nr = streams.encode_leaf_batch(
        g, r, k=k, nb=1, m=n, size=n, pair_seeds=pk, pair_signs=ps,
        k_mask=km, mask_p=sa.p, mask_q=sa.q, leaf_id=0)
    expected = ((g - nr) * alive[:, None]).sum(0)
    recovered = streams.decode_leaf_batch(
        st, nb=1, m=n, size=n, alive=alive, pair_seeds=pk, pair_signs=ps,
        k_mask=km, mask_p=sa.p, mask_q=sa.q, leaf_id=0)
    np.testing.assert_allclose(np.asarray(recovered), np.asarray(expected),
                               rtol=1e-4, atol=1e-5)
    naive = streams.decode_leaf_batch(st, nb=1, m=n, size=n, alive=alive)
    assert float(jnp.max(jnp.abs(naive - expected))) > 0.1  # masks uncancelled


def test_engine_matches_reference_single_client_path():
    """The batched engine and the protocol-reference path (encode_leaf +
    masks.client_masks) produce identical streams — same counter-based
    draws, same unified-stream slots (the engine adds one gated self-slot
    block)."""
    n, k, C = 400, 8, 3
    sa = SecureAggConfig(mask_ratio=0.3, seed=21)
    parts = [0, 1, 2]
    km = sa.k_mask_for(n, C)
    g, r = _batch(jax.random.key(3), C, n)
    pk, ps = streams.pair_seed_matrix(sa, parts, round_t=7)
    st, nr = streams.encode_leaf_batch(
        g, r, k=k, nb=1, m=n, size=n, pair_seeds=pk, pair_signs=ps,
        k_mask=km, mask_p=sa.p, mask_q=sa.q, leaf_id=0)
    for ci, c in enumerate(parts):
        mask = client_masks(sa, c, parts, 7, 0, n, km)
        ref = encode_leaf(g[ci], r[ci], k, THGS, mask)
        eng_idx = np.asarray(st.indices[ci, 0])
        eng_val = np.asarray(st.values[ci, 0])
        ref_idx = np.asarray(ref.stream.indices)
        ref_val = np.asarray(ref.stream.values)
        # top-k block identical
        np.testing.assert_array_equal(eng_idx[:k], ref_idx[:k])
        # mask blocks: engine layout is [self-slot | peers in id order], the
        # reference skips the self slot; engine self-slot values are 0
        self_pos = parts.index(c)
        eng_mask_idx = eng_idx[k:].reshape(C, km)
        eng_mask_val = eng_val[k:].reshape(C, km)
        ref_mask_idx = ref_idx[k:].reshape(C - 1, km)
        ref_mask_val = ref_val[k:].reshape(C - 1, km)
        peer_rows = [i for i in range(C) if i != self_pos]
        np.testing.assert_array_equal(eng_mask_idx[peer_rows], ref_mask_idx)
        np.testing.assert_allclose(eng_mask_val[peer_rows], ref_mask_val,
                                   rtol=1e-6)
        assert (eng_mask_val[self_pos] == 0.0).all()
        np.testing.assert_allclose(np.asarray(nr[ci]),
                                   np.asarray(ref.residual.reshape(-1)),
                                   rtol=1e-6, atol=1e-7)


def test_mask_streams_all_pairs_match_masks_py():
    """The fused counter-based mask pass reproduces masks.pair_mask
    draw-for-draw (bit-identical indices AND values)."""
    sa = SecureAggConfig(mask_ratio=0.5, seed=13)
    n, km = 256, 17
    pk, ps = streams.pair_seed_matrix(sa, [4, 9], round_t=5)
    ref = pair_mask(sa, 4, 9, 5, 3, n, km)
    idx, vals = streams.mask_streams_all_pairs(
        pk, ps, 1, km, n, p=sa.p, q=sa.q, leaf_id=3)
    # client 0 (id 4): peer block 1 holds its mask toward id 9
    np.testing.assert_array_equal(np.asarray(ref.indices),
                                  np.asarray(idx[0, 0, km:2 * km]))
    np.testing.assert_array_equal(np.asarray(ref.values),
                                  np.asarray(vals[0, 0, km:2 * km]))


def test_blocked_conservation_via_engine():
    """stream + residual reconstruct the input exactly (blocked layout)."""
    from repro.core.blocked import decode_blocked_sum, encode_leaf_blocked

    for size, n_blocks in [(50, 1), (1000, 4), (4097, 8)]:
        g = jax.random.normal(jax.random.key(size), (size,))
        r = jnp.zeros_like(g)
        stream, new_r = encode_leaf_blocked(g, r, k_block=3, n_blocks=n_blocks)
        dense = decode_blocked_sum(stream.indices[None], stream.values[None],
                                   size, n_blocks, weight=1.0)
        np.testing.assert_allclose(np.asarray(dense + new_r.reshape(-1)),
                                   np.asarray(g), rtol=1e-5, atol=1e-5)


def test_blocked_masks_cancel_via_engine():
    """shard_map-style traced-self-id masks cancel across participants."""
    from repro.core.blocked import decode_blocked_sum, encode_leaf_blocked

    size, nb, kb, km, n_fed = 600, 4, 5, 7, 3
    key = jax.random.key(8)
    mask_key = jax.random.fold_in(key, 999)
    idx_all, val_all, expected = [], [], jnp.zeros(size)
    for me in range(n_fed):
        g = jax.random.normal(jax.random.fold_in(key, me), (size,))
        stream, new_r = encode_leaf_blocked(
            g, jnp.zeros_like(g), kb, nb, mask_key=mask_key,
            k_mask_block=km, n_peers=n_fed, self_id=jnp.int32(me))
        idx_all.append(stream.indices)
        val_all.append(stream.values)
        expected = expected + (g - new_r.reshape(-1))
    dense = decode_blocked_sum(jnp.stack(idx_all), jnp.stack(val_all),
                               size, nb, weight=1.0)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(expected),
                               rtol=1e-4, atol=1e-4)


def test_sampled_selector_batched():
    g, r = _batch(jax.random.key(17), 3, 5000)
    st, nr = streams.encode_leaf_batch(
        g, r, k=50, nb=1, m=5000, size=5000, selector="sampled",
        sample_frac=0.05)
    dense = streams.decode_leaf_batch(st, nb=1, m=5000, size=5000)
    np.testing.assert_allclose(np.asarray(dense), np.asarray((g - nr).sum(0)),
                               rtol=1e-4, atol=1e-5)


def test_run_round_with_dropout_and_weights():
    """End-to-end run_round: dropped client excluded, masks reconstructed,
    weighted mean over survivors applied, error feedback preserved."""
    from repro.core.fedavg import init_state, run_round
    from repro.core.types import FedConfig

    dim = 40
    key = jax.random.key(0)
    true_w = jnp.linspace(1.0, 3.0, dim).reshape(dim, 1)

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] - y) ** 2)

    params = {"w": jnp.zeros((dim, 1))}
    fed = FedConfig(n_clients=4, clients_per_round=4, local_steps=2,
                    local_batch=8, local_lr=0.05, rounds=6)
    thgs = THGSConfig(s0=0.5, alpha=1.0, s_min=0.3, time_varying=False)
    sa = SecureAggConfig(mask_ratio=0.1, seed=3)
    st = init_state(params, fed)
    weights = {0: 2.0, 1: 1.0, 2: 1.0, 3: 1.0}
    for r in range(fed.rounds):
        batches = {}
        for c in range(4):
            k = jax.random.fold_in(key, r * 10 + c)
            x = jax.random.normal(k, (2, 8, dim))
            batches[c] = (x, x @ true_w)
        st = run_round(st, batches, loss_fn, fed, thgs, sa,
                       client_weights=weights, dropped=[3] if r % 2 else [])
    err = float(jnp.max(jnp.abs(st.params["w"] - true_w)))
    assert err < 2.0, err  # converging despite drops
    # dropped client's round kept its error feedback (nothing zeroed to loss)
    assert st.comm_log[-1].n_clients == 4
