"""repro.lint: fixture-driven good/bad pairs per check id, suppressions,
the repro.lint/v1 JSON schema, and the committed-tree gate pins
(DESIGN.md §14)."""

import json
import os

import pytest

from repro import lint
from repro.lint import report
from repro.lint.__main__ import main

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# --------------------------------------------------------------------------
# fixture snippets: (virtual path, source) per check id; the path places the
# snippet inside the check's scope (bench suite, decode module, kernels/...)
# --------------------------------------------------------------------------

GOOD = {
    "RPL001": (
        "src/repro/sim/clock.py",
        (
            "import time\n"
            "import zlib\n"
            "\n"
            "\n"
            "def digest(name):\n"
            "    return zlib.crc32(name.encode())\n"
            "\n"
            "\n"
            "def wall(t0):\n"
            "    return time.perf_counter() - t0\n"
            "\n"
            "\n"
            "def stable(xs):\n"
            "    return sorted(set(xs))\n"
        ),
    ),
    "RPL002": (
        "src/repro/bench/good_bench.py",
        (
            "from repro.bench.timing import entry, measure\n"
            "\n"
            "\n"
            "def entries(quick=False):\n"
            "    us = measure(lambda: None, reps=3)\n"
            "    return [entry('agg/noop', us, reps=3)]\n"
        ),
    ),
    "RPL003": (
        "src/repro/core/wire.py",
        (
            "from repro.core.codecs import reject_codec_with_masks\n"
            "\n"
            "\n"
            "def encode(updates, codec='f32', k_mask=0):\n"
            "    reject_codec_with_masks(codec, k_mask)\n"
            "    return updates\n"
        ),
    ),
    "RPL004": (
        "src/repro/core/streams.py",
        (
            "import jax.numpy as jnp\n"
            "\n"
            "\n"
            "def combine(parts):\n"
            "    return jnp.concatenate(parts, axis=-1)\n"
        ),
    ),
    "RPL005": (
        "kernels/goodop.py",
        (
            "from jax.experimental import pallas as pl\n"
            "\n"
            "\n"
            "def _kernel(x_ref, o_ref):\n"
            "    o_ref[...] = x_ref[...]\n"
            "\n"
            "\n"
            "def goodop(x, *, interpret=False):\n"
            "    return pl.pallas_call(_kernel, interpret=interpret)(x)\n"
        ),
    ),
    "RPL006": (
        "src/repro/core/jitted.py",
        (
            "import functools\n"
            "\n"
            "import jax\n"
            "\n"
            "\n"
            "@functools.partial(jax.jit, static_argnames=('k',))\n"
            "def scale(x, k, w=None):\n"
            "    if k > 0 and w is not None:\n"
            "        return x * w * k\n"
            "    return x\n"
        ),
    ),
    "RPL007": (
        "src/repro/sim/sidecar.py",
        (
            "import json\n"
            "import os\n"
            "\n"
            "\n"
            "def write(path, obj):\n"
            "    tmp = path + '.tmp'\n"
            "    with open(tmp, 'w') as f:\n"
            "        json.dump(obj, f)\n"
            "    os.replace(tmp, path)\n"
        ),
    ),
}

BAD = {
    "RPL001": (
        "src/repro/sim/clock.py",
        (
            "import random\n"
            "import time\n"
            "\n"
            "\n"
            "def seed_for(name):\n"
            "    return hash(name) % 100\n"
            "\n"
            "\n"
            "def stamp():\n"
            "    return time.time()\n"
            "\n"
            "\n"
            "def pick(xs):\n"
            "    return random.choice(xs)\n"
            "\n"
            "\n"
            "def order(xs):\n"
            "    return list(set(xs))\n"
        ),
    ),
    "RPL002": (
        "src/repro/bench/bad_bench.py",
        (
            "from repro.bench.timing import entry, time_us\n"
            "\n"
            "\n"
            "def entries(quick=False):\n"
            "    us = time_us(lambda: None, reps=2)\n"
            "    return [entry('agg/noop', us, reps=2)]\n"
        ),
    ),
    "RPL003": (
        "src/repro/core/wire.py",
        (
            "def encode(updates, codec='f32', k_mask=0):\n"
            "    return updates, codec, k_mask\n"
        ),
    ),
    "RPL004": (
        "src/repro/core/streams.py",
        (
            "import jax\n"
            "\n"
            "\n"
            "def combine(parts):\n"
            "    return jax.lax.psum(parts, 'clients')\n"
        ),
    ),
    "RPL005": (
        "kernels/badop.py",
        (
            "from jax.experimental import pallas as pl\n"
            "\n"
            "\n"
            "def _kernel(x_ref, o_ref):\n"
            "    o_ref[...] = x_ref[...]\n"
            "\n"
            "\n"
            "def badop(x):\n"
            "    return pl.pallas_call(_kernel)(x)\n"
        ),
    ),
    "RPL006": (
        "src/repro/core/jitted.py",
        (
            "import functools\n"
            "\n"
            "import jax\n"
            "\n"
            "\n"
            "@functools.partial(jax.jit, static_argnames=('k',))\n"
            "def scale(x, k):\n"
            "    if x > 0:\n"
            "        return x * k\n"
            "    return x\n"
        ),
    ),
    "RPL007": (
        "src/repro/sim/sidecar.py",
        (
            "import json\n"
            "\n"
            "\n"
            "def write(path, obj):\n"
            "    with open(path, 'w') as f:\n"
            "        json.dump(obj, f)\n"
        ),
    ),
}

CHECK_IDS = sorted(GOOD)


def _write_fixture(tmp_path, rel_path, source):
    path = tmp_path / rel_path
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(source)
    if rel_path.startswith("kernels/"):
        ref = path.parent / "ref.py"
        if not ref.exists():
            ref.write_text("def goodop_ref(x):\n    return x\n")
    return path


# ------------------------------------------------------------- check pairs
@pytest.mark.parametrize("check_id", CHECK_IDS)
def test_bad_fixture_flags_exactly_this_check(check_id, tmp_path):
    rel_path, source = BAD[check_id]
    path = _write_fixture(tmp_path, rel_path, source)
    findings = lint.lint_file(str(path), select={check_id})
    assert findings, f"{check_id} bad fixture produced no findings"
    assert {f.check for f in findings} == {check_id}
    assert all(not f.suppressed for f in findings)


@pytest.mark.parametrize("check_id", CHECK_IDS)
def test_good_fixture_is_clean(check_id, tmp_path):
    rel_path, source = GOOD[check_id]
    path = _write_fixture(tmp_path, rel_path, source)
    assert lint.lint_file(str(path), select={check_id}) == []


@pytest.mark.parametrize("check_id", CHECK_IDS)
def test_gate_exits_nonzero_on_bad_fixture(check_id, tmp_path, capsys):
    rel_path, source = BAD[check_id]
    _write_fixture(tmp_path, rel_path, source)
    assert main([str(tmp_path), "--gate", "--select", check_id]) == 1
    capsys.readouterr()


def test_rpl001_flags_pr5_hash_pattern_reintroduced():
    """Acceptance pin: the exact PR-5 datasets.py bug must be caught."""
    path = os.path.join(ROOT, "src", "repro", "data", "datasets.py")
    with open(path) as f:
        text = f.read()
    bad = text.replace(
        'zlib.crc32(f"{spec.name}/17".encode())', 'hash(f"{spec.name}/17")'
    )
    assert bad != text, "datasets.py digest line moved; update this test"
    findings = lint.lint_source(bad, path=path, select={"RPL001"})
    assert [f.check for f in findings] == ["RPL001"]
    assert "PYTHONHASHSEED" in findings[0].message
    # ... and the committed (crc32) version is clean
    assert lint.lint_source(text, path=path, select={"RPL001"}) == []


# ------------------------------------------------------- per-check details
def test_rpl001_message_variety():
    _, source = BAD["RPL001"]
    findings = lint.lint_source(source, path="src/repro/sim/clock.py")
    blob = " ".join(f.message for f in findings)
    for needle in ("hash()", "time.time()", "random", "sorted"):
        assert needle in blob, needle


def test_rpl002_out_of_scope_paths_not_flagged():
    _, source = BAD["RPL002"]
    assert lint.lint_source(source, path="src/repro/bench/timing.py") == []
    assert lint.lint_source(source, path="src/repro/sim/engine.py") == []


def test_rpl002_flags_raw_perf_counter_and_missing_measure():
    source = (
        "import time\n"
        "\n"
        "\n"
        "def entries(quick=False):\n"
        "    t0 = time.perf_counter()\n"
        "    return [('agg/noop', time.perf_counter() - t0)]\n"
    )
    findings = lint.lint_source(source, path="src/repro/bench/x_bench.py")
    messages = " ".join(f.message for f in findings)
    assert "perf_counter" in messages
    assert "never calls timing.measure" in messages


def test_rpl003_private_helpers_exempt():
    source = (
        "def _encode(updates, codec='f32', k_mask=0):\n"
        "    return updates, codec, k_mask\n"
    )
    assert lint.lint_source(source, path="src/repro/core/wire.py") == []


def test_rpl004_out_of_decode_scope_not_flagged():
    _, source = BAD["RPL004"]
    assert lint.lint_source(source, path="src/repro/core/fedavg.py") == []


def test_rpl005_twin_override_comment(tmp_path):
    source = (
        "from jax.experimental import pallas as pl\n"
        "\n"
        "\n"
        "def weird(x, *, interpret=False):  # repro-lint: twin=goodop_ref\n"
        "    return pl.pallas_call(lambda i, o: None, interpret=interpret)(x)\n"
    )
    path = _write_fixture(tmp_path, "kernels/weird.py", source)
    assert lint.lint_file(str(path), select={"RPL005"}) == []


def test_rpl005_real_kernel_modules_satisfy_the_contract():
    """Every committed pallas_call wrapper has its ref twin + interpret."""
    kdir = os.path.join(ROOT, "src", "repro", "kernels")
    for name in sorted(os.listdir(kdir)):
        if not name.endswith(".py"):
            continue
        path = os.path.join(kdir, name)
        assert lint.lint_file(path, select={"RPL005"}) == [], name


def test_rpl006_static_argnames_and_is_none_pass():
    _, source = GOOD["RPL006"]
    assert lint.lint_source(source, path="src/repro/core/jitted.py") == []


def test_rpl006_undecorated_functions_out_of_scope():
    source = "def f(x):\n    if x > 0:\n        return x\n    return -x\n"
    assert lint.lint_source(source, path="src/repro/core/free.py") == []


def test_rpl007_tmp_spellings_all_pass():
    # the four tmp-path spellings the codebase actually uses: literal,
    # f-string, string concatenation, and a name resolved through a simple
    # assignment chain
    for body in (
        "with open('/tmp/x.json.tmp', 'w') as f:\n    json.dump(obj, f)\n",
        "with open(f'{path}.tmp', 'w') as f:\n    json.dump(obj, f)\n",
        "with open(path + '.tmp', 'w') as f:\n    json.dump(obj, f)\n",
        "tmp = path + '.tmp'\nwith open(tmp, 'w') as f:\n"
        "    json.dump(obj, f)\n",
    ):
        source = "import json\n\npath = 'out.json'\nobj = {}\n" + body
        assert lint.lint_source(source, path="src/repro/sim/w.py",
                                select={"RPL007"}) == [], body


def test_rpl007_read_mode_and_fp_kwarg():
    # reads are never flagged; dump(fp=...) into a bare-path handle is
    src = ("import json\n\n"
           "def load(path):\n"
           "    with open(path) as f:\n"
           "        return json.load(f)\n")
    assert lint.lint_source(src, path="src/repro/sim/r.py",
                            select={"RPL007"}) == []
    src = ("import json\n\n"
           "def write(path, obj):\n"
           "    with open(path, mode='w') as f:\n"
           "        json.dump(obj, fp=f)\n")
    findings = lint.lint_source(src, path="src/repro/sim/w.py",
                                select={"RPL007"})
    assert [f.check for f in findings] == ["RPL007"]


def test_rpl007_test_files_exempt():
    _, source = BAD["RPL007"]
    assert lint.lint_source(source, path="tests/test_sidecar.py",
                            select={"RPL007"}) == []


# ------------------------------------------------------------ suppressions
def test_suppression_same_line():
    source = "import time\n\nT0 = time.time()  # repro-lint: disable=RPL001\n"
    findings = lint.lint_source(source, path="src/repro/x.py")
    assert [f.suppressed for f in findings] == [True]


def test_suppression_disable_next():
    source = (
        "import time\n"
        "\n"
        "# repro-lint: disable-next=RPL001\n"
        "T0 = time.time()\n"
    )
    findings = lint.lint_source(source, path="src/repro/x.py")
    assert [f.suppressed for f in findings] == [True]


def test_suppression_disable_file():
    source = (
        "# repro-lint: disable-file=RPL001\n"
        "import time\n"
        "\n"
        "T0 = time.time()\n"
        "T1 = time.time()\n"
    )
    findings = lint.lint_source(source, path="src/repro/x.py")
    assert [f.suppressed for f in findings] == [True, True]


def test_suppression_wrong_id_does_not_apply():
    source = "import time\n\nT0 = time.time()  # repro-lint: disable=RPL002\n"
    findings = lint.lint_source(source, path="src/repro/x.py")
    assert [f.suppressed for f in findings] == [False]


def test_suppressed_findings_do_not_fail_the_gate(tmp_path, capsys):
    path = tmp_path / "x.py"
    path.write_text(
        "import time\n\nT0 = time.time()  # repro-lint: disable=RPL001\n"
    )
    assert main([str(path), "--gate"]) == 0
    capsys.readouterr()


# ------------------------------------------------------------- JSON schema
def test_json_report_schema_roundtrip():
    _, source = BAD["RPL001"]
    findings = lint.lint_source(source, path="src/repro/sim/clock.py")
    doc = report.make_doc(findings, n_files=1, paths=["src"])
    restored = json.loads(json.dumps(doc))
    assert report.validate_doc(restored) == []
    assert restored["schema"] == lint.SCHEMA_VERSION
    assert restored["files"] == 1
    assert len(restored["findings"]) == len(findings)
    assert sum(restored["counts"].values()) == len(findings)


def test_validate_doc_rejects_malformed():
    assert report.validate_doc({"schema": "nope"})
    assert report.validate_doc([])
    good = report.make_doc([], n_files=1, paths=["src"])
    bad_id = dict(good)
    bad_id["findings"] = [
        {"check": "X1", "path": "a.py", "line": 1, "col": 1, "message": "m"}
    ]
    bad_id["counts"] = {"X1": 1}
    assert any("RPLxxx" in e for e in report.validate_doc(bad_id))
    bad_counts = dict(good)
    bad_counts["counts"] = {"RPL001": 7}
    assert any("counts" in e for e in report.validate_doc(bad_counts))


def test_cli_json_out(tmp_path, capsys):
    src_file = tmp_path / "x.py"
    src_file.write_text("import time\n\nT0 = time.time()\n")
    out = tmp_path / "lint.json"
    rc = main([str(src_file), "--format", "json", "--out", str(out)])
    capsys.readouterr()
    assert rc == 0  # reporting without --gate never fails the process
    doc = json.loads(out.read_text())
    assert report.validate_doc(doc) == []
    assert doc["counts"] == {"RPL001": 1}


# ------------------------------------------------------------ CLI behavior
def test_parse_error_is_a_gating_finding(tmp_path, capsys):
    path = tmp_path / "broken.py"
    path.write_text("def f(:\n")
    findings = lint.lint_file(str(path))
    assert [f.check for f in findings] == [lint.PARSE_ERROR_ID]
    assert main([str(path), "--gate"]) == 1
    capsys.readouterr()


def test_vacuous_gate_fails(tmp_path, capsys):
    empty = tmp_path / "empty"
    empty.mkdir()
    assert main([str(empty), "--gate"]) == 1
    capsys.readouterr()


def test_unknown_check_id_is_a_usage_error(capsys):
    assert main(["--select", "RPL999", "src"]) == 2
    capsys.readouterr()


def test_list_checks(capsys):
    assert main(["--list-checks"]) == 0
    out = capsys.readouterr().out
    for check_id in CHECK_IDS:
        assert check_id in out


# ------------------------------------------------------- committed-tree pin
def test_committed_tree_is_lint_clean(capsys):
    """Acceptance pin: `python -m repro.lint src` exits 0 on the tree, and
    the full CI gate (src + tests + examples + benchmarks) stays clean."""
    paths = [os.path.join(ROOT, p) for p in ("src", "tests")]
    assert main(paths) == 0
    extra = [os.path.join(ROOT, p) for p in ("examples", "benchmarks")]
    assert main([*paths, *extra, "--gate"]) == 0
    capsys.readouterr()
