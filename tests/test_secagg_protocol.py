"""The repro/secagg round protocol: Shamir share algebra, threshold-gated
dropout recovery, bit-identical mask reconstruction, and end-to-end Eq. 5
exactness against an engine-independent dense masked-top-k reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep; tier-1 must collect without it
from hypothesis import given, settings, strategies as st

from repro.core import streams
from repro.core.fedavg import init_state, run_round
from repro.core.masks import client_masks, dh_private, dh_public
from repro.core.types import FedConfig, SecureAggConfig, THGSConfig
from repro.secagg import RoundProtocol, ThresholdError, shamir

THGS = THGSConfig(s0=0.2, alpha=0.9, s_min=0.05, time_varying=False)


# -------------------------------------------------------------------- shamir
@given(secret=st.integers(0, shamir.PRIME - 1), n=st.integers(2, 8),
       data=st.data())
@settings(max_examples=40, deadline=None)
def test_shamir_roundtrip_any_t_subset(secret, n, data):
    t = data.draw(st.integers(2, n))
    points = list(range(1, n + 1))
    shares = shamir.share(secret, points, t, tag="prop")
    subset = data.draw(st.permutations(points))[:data.draw(st.integers(t, n))]
    assert shamir.reconstruct({x: shares[x] for x in subset}) == secret


def test_shamir_below_threshold_reveals_nothing_useful():
    secret = 0xDEADBEEF
    shares = shamir.share(secret, [1, 2, 3, 4, 5], 3, tag="leak")
    # t-1 shares interpolate to SOME field element, not the secret
    assert shamir.reconstruct({1: shares[1], 2: shares[2]}) != secret
    with pytest.raises(ValueError):
        shamir.share(secret, [1, 1, 2], 2, tag="dup")
    with pytest.raises(ValueError):
        shamir.share(secret, [1, 2], 3, tag="t>n")


# ------------------------------------------------------------------ protocol
def test_protocol_reconstructs_keys_and_seeds_bit_identical():
    sa = SecureAggConfig(mask_ratio=0.1, seed=42, threshold=0.6)
    parts = [2, 3, 7, 11, 13]
    proto = RoundProtocol.setup(sa, parts, round_t=5)
    seeds, _ = proto.pair_seed_matrix()
    rec = proto.recover_seeds(survivors=[2, 7, 11, 13], dropped=[3])
    orig, got = np.asarray(seeds), np.asarray(rec)
    d = parts.index(3)
    for s in (0, 2, 3, 4):
        assert got[s, d] == orig[s, d] and got[d, s] == orig[d, s]
    # only survivor<->dropped entries are filled
    assert got[0, 2] == 0 and got[2, 4] == 0
    # the Shamir recombination really returns the DH private key
    pts = {v + 1: proto.shares[3][v + 1] for v in [2, 7, 11]}
    assert shamir.reconstruct(pts) == dh_private(sa.seed, 3)
    assert dh_public(dh_private(sa.seed, 3)) == proto.publics[3]


def test_protocol_threshold_abort_and_validation():
    sa = SecureAggConfig(seed=1, threshold=0.75)
    proto = RoundProtocol.setup(sa, [0, 1, 2, 3], round_t=0)
    assert proto.t == 3
    with pytest.raises(ThresholdError):
        proto.recover_seeds(survivors=[0, 1], dropped=[2, 3])
    with pytest.raises(ValueError):
        proto.recover_seeds(survivors=[0, 1, 2], dropped=[9])
    with pytest.raises(ValueError):
        proto.recover_seeds(survivors=[0, 1, 2], dropped=[2])
    assert proto.n_phase1_shares == 12
    assert proto.n_recovery_shares(2) == 6


# --------------------------------------------- end-to-end Eq. 5 property test
def _dense_reference(g_np, sa, parts, round_t, k, km, survivors):
    """Engine-independent dense masked-top-k sum: per surviving client,
    select top-k(|g|) ∪ mask-support (dedup!) and sum the raw values."""
    n = g_np.shape[1]
    total = np.zeros(n, np.float64)
    for ci, c in enumerate(parts):
        if c not in survivors:
            continue
        topk = np.argsort(-np.abs(g_np[ci]))[:k]
        mask = client_masks(sa, c, parts, round_t, 0, n, km)
        sel = np.union1d(topk, np.asarray(mask.indices))
        total[sel] += g_np[ci][sel].astype(np.float64)
    return total


@given(n_clients=st.integers(2, 5), seed=st.integers(0, 2**16),
       mask_ratio=st.floats(0.05, 0.5), data=st.data())
@settings(max_examples=10, deadline=None)
def test_decoded_sum_equals_dense_reference_under_dropout(
        n_clients, seed, mask_ratio, data):
    """For random client counts, sparse rates and arbitrary survivor subsets
    >= threshold: the decoded aggregate (with Shamir-reconstructed mask
    cancellation) equals the dense masked-top-k reference over survivors."""
    n, k, round_t = 300, 8, 2
    sa = SecureAggConfig(mask_ratio=mask_ratio, seed=seed, threshold=0.5)
    parts = sorted(data.draw(
        st.sets(st.integers(0, 19), min_size=n_clients, max_size=n_clients)))
    proto = RoundProtocol.setup(sa, parts, round_t)
    survivors = sorted(data.draw(
        st.sets(st.sampled_from(parts), min_size=proto.t,
                max_size=len(parts))))
    dropped = [c for c in parts if c not in survivors]

    g = jax.random.normal(jax.random.key(seed), (len(parts), n))
    km = sa.k_mask_for(n, len(parts))
    seeds, signs = proto.pair_seed_matrix()
    st_b, nr = streams.encode_leaf_batch(
        g, jnp.zeros_like(g), k=k, nb=1, m=n, size=n,
        pair_seeds=seeds, pair_signs=signs, k_mask=km,
        mask_p=sa.p, mask_q=sa.q, leaf_id=0)
    alive = jnp.asarray([c in survivors for c in parts])
    if dropped:
        rec_seeds = proto.recover_seeds(survivors, dropped)
        # reconstruction is bit-identical to the encode-time seeds at every
        # survivor<->dropped entry
        o, r = np.asarray(seeds), np.asarray(rec_seeds)
        for s in np.flatnonzero(np.asarray(alive)):
            for d in np.flatnonzero(~np.asarray(alive)):
                assert r[s, d] == o[s, d]
    else:
        rec_seeds = None
    decoded = streams.decode_leaf_batch(
        st_b, nb=1, m=n, size=n,
        alive=alive if dropped else None,
        pair_seeds=rec_seeds, pair_signs=signs if dropped else None,
        k_mask=km, mask_p=sa.p, mask_q=sa.q, leaf_id=0)
    expected = _dense_reference(np.asarray(g), sa, parts, round_t, k, km,
                                set(survivors))
    np.testing.assert_allclose(np.asarray(decoded), expected,
                               rtol=1e-4, atol=1e-5)


@given(n_clients=st.integers(2, 6), seed=st.integers(0, 1000),
       round_t=st.integers(0, 30))
@settings(max_examples=15, deadline=None)
def test_mask_streams_cancel_to_zero(n_clients, seed, round_t):
    """Aggregated mask values alone cancel to exact zero (f64 accumulation;
    the f32 scatter is exact up to 1 ulp on >= 3-way support collisions)."""
    sa = SecureAggConfig(mask_ratio=0.4, seed=seed)
    n = 400
    km = sa.k_mask_for(n, n_clients)
    seeds, signs = streams.pair_seed_matrix(sa, list(range(n_clients)),
                                            round_t)
    idx, vals = streams.mask_streams_all_pairs(
        seeds, signs, 1, km, n, p=sa.p, q=sa.q, leaf_id=0)
    total = np.zeros(n, np.float64)
    np.add.at(total, np.asarray(idx).reshape(-1),
              np.asarray(vals, np.float64).reshape(-1))
    assert np.abs(total).max() == 0.0


# ------------------------------------------- duplicate-support gate, e2e
def test_duplicate_support_not_double_counted():
    """masks.PairMask's `may repeat` contract, end to end: with a leaf so
    small that mask support collides heavily (and overlaps top-k), the
    first-occurrence gate still transmits each gradient value exactly once
    and the decoded sum equals the dense reference."""
    n, k, C = 13, 4, 3
    sa = SecureAggConfig(mask_ratio=1.0, seed=3)   # k_mask = 4 on 13 slots
    parts = [0, 1, 2]
    km = sa.k_mask_for(n, C)
    assert km * (C - 1) + k > n          # unions MUST collide
    proto = RoundProtocol.setup(sa, parts, round_t=1)
    seeds, signs = proto.pair_seed_matrix()
    g = jax.random.normal(jax.random.key(5), (C, n))
    st_b, nr = streams.encode_leaf_batch(
        g, jnp.zeros_like(g), k=k, nb=1, m=n, size=n,
        pair_seeds=seeds, pair_signs=signs, k_mask=km,
        mask_p=sa.p, mask_q=sa.q, leaf_id=0)
    # duplicates actually occurred in at least one client's stream
    assert any(
        len(np.unique(np.asarray(st_b.indices[ci, 0]))) < st_b.k_total
        for ci in range(C))
    decoded = streams.decode_leaf_batch(st_b, nb=1, m=n, size=n)
    expected = _dense_reference(np.asarray(g), sa, parts, 1, k, km,
                                set(parts))
    np.testing.assert_allclose(np.asarray(decoded), expected,
                               rtol=1e-4, atol=1e-5)
    # and the error feedback kept exactly the untransmitted mass
    np.testing.assert_allclose(
        np.asarray((g - nr).sum(0)), expected, rtol=1e-4, atol=1e-5)


# --------------------------------------------------------- run_round plumbing
def _linreg(dim):
    params = {"w": jnp.zeros((dim, 1))}

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] - y) ** 2)

    return params, loss_fn


def test_run_round_secagg_matches_unmasked_run():
    """The acceptance check: a multi-round secure-agg run with dropout
    produces the same decoded updates as the identical run without masking.
    'Without masking' keeps the same pair seeds but a zero-width mask
    distribution (p = q = 0): the union support and the gradient slots are
    bit-identical between the two runs, the mask values are exactly zero —
    so any difference could only come from masks failing to cancel (or from
    recovery failing to reconstruct a dropped client's masks)."""
    dim, C = 60, 4
    params, loss_fn = _linreg(dim)
    fed = FedConfig(n_clients=C, clients_per_round=C, local_steps=2,
                    local_batch=8, local_lr=0.05, rounds=4)
    key = jax.random.key(0)
    true_w = jnp.linspace(-1.0, 1.0, dim).reshape(dim, 1)

    def batches_for(r):
        out = {}
        for c in range(C):
            kk = jax.random.fold_in(key, r * 10 + c)
            x = jax.random.normal(kk, (2, 8, dim))
            out[c] = (x, x @ true_w)
        return out

    # identical sampler stream: same batches, same dropout schedule
    dropped_per_round = [(), (2,), (), (1, 3)]
    sa_on = SecureAggConfig(mask_ratio=0.2, seed=9, threshold=0.5)
    sa_zero = SecureAggConfig(mask_ratio=0.2, seed=9, threshold=0.5,
                              p=0.0, q=0.0)
    st_on = init_state(params, fed)
    st_zero = init_state(params, fed)
    for r in range(fed.rounds):
        st_on = run_round(st_on, batches_for(r), loss_fn, fed, THGS, sa_on,
                          dropped=dropped_per_round[r])
        st_zero = run_round(st_zero, batches_for(r), loss_fn, fed, THGS,
                            sa_zero, dropped=dropped_per_round[r])
    np.testing.assert_allclose(np.asarray(st_on.params["w"]),
                               np.asarray(st_zero.params["w"]),
                               rtol=1e-4, atol=1e-6)
    for c in range(C):
        np.testing.assert_allclose(np.asarray(st_on.residuals[c]["w"]),
                                   np.asarray(st_zero.residuals[c]["w"]),
                                   rtol=1e-4, atol=1e-6)
    # the masked run's uploads were actually masked (values differ), and the
    # secure round logged its control traffic
    rec = st_on.comm_log[1]
    assert rec.threshold == sa_on.t_for(C)
    assert rec.share_upload_bits > 0 and rec.recovery_upload_bits > 0
    assert st_on.comm_log[0].recovery_upload_bits == 0


def test_run_round_aborts_below_threshold():
    dim, C = 20, 4
    params, loss_fn = _linreg(dim)
    fed = FedConfig(n_clients=C, clients_per_round=C, local_steps=1,
                    local_batch=4, local_lr=0.05, rounds=1)
    sa = SecureAggConfig(mask_ratio=0.2, seed=2, threshold=1.0)  # t = C
    st_x = init_state(params, fed)
    key = jax.random.key(1)
    batches = {c: (jax.random.normal(jax.random.fold_in(key, c), (1, 4, dim)),
                   jnp.zeros((1, 4, 1)))
               for c in range(C)}
    with pytest.raises(ThresholdError):
        run_round(st_x, batches, loss_fn, fed, THGS, sa, dropped=[3])
