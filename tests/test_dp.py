"""Distributed DP under secure aggregation (core/dp.py, DESIGN.md §15):
grid-exact noise, exact noise+mask composition over survivor subsets >= t,
sigma=0/clip=inf bit-identity with plain secagg, the RDP accountant, and
bit-identical resume of the noise stream."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs, dp, streams
from repro.core.types import SecureAggConfig, THGSConfig
from repro.kernels import ref as kref
from repro.sim import CommLedger, SimConfig, Simulation
from repro.sim.config import SimConfig as _SimConfig  # noqa: F401 (re-export)

GRID = 2.0 ** -24


# ------------------------------------------------------------- noise sampler
def test_noise_stream_on_grid_deterministic_and_seed_sensitive():
    seeds = jnp.arange(64, dtype=jnp.uint32)
    a = kref.dp_noise_stream_ref(seeds, 4, 16, sigma=0.5)
    b = kref.dp_noise_stream_ref(seeds, 4, 16, sigma=0.5)
    assert np.array_equal(np.asarray(a), np.asarray(b))  # replayable
    units = np.asarray(a, np.float64) / GRID
    assert np.array_equal(units, np.round(units))        # on the 2^-24 grid
    c = kref.dp_noise_stream_ref(seeds + 1, 4, 16, sigma=0.5)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    # sigma=0 noise is exactly zero (round(0 * z) stays 0 on the grid)
    z = kref.dp_noise_stream_ref(seeds, 4, 16, sigma=0.0)
    assert np.array_equal(np.asarray(z), np.zeros_like(np.asarray(z)))


def test_noise_stream_distribution():
    seeds = jnp.arange(512, dtype=jnp.uint32)
    n = np.asarray(kref.dp_noise_stream_ref(seeds, 4, 64, sigma=0.25))
    assert abs(float(n.mean())) < 0.005
    assert abs(float(n.std()) - 0.25) < 0.01


# ------------------------------------------------------------------ clipping
def test_clip_scales_violators_and_is_noop_inside_bound():
    upd = {"w": jnp.stack([jnp.ones(16) * 2.0, jnp.ones(16) * 0.01]),
           "b": jnp.stack([jnp.ones(4) * 2.0, jnp.ones(4) * 0.01])}
    out = dp.clip_client_updates(upd, clip=1.0)
    norm0 = math.sqrt(sum(float(jnp.sum(jnp.square(out[k][0])))
                          for k in upd))
    assert abs(norm0 - 1.0) < 1e-5                 # clipped onto the sphere
    for k in upd:                                   # compliant client: bitwise
        assert np.array_equal(np.asarray(out[k][1]), np.asarray(upd[k][1]))
    # clip=inf touches nothing, bitwise
    out_inf = dp.clip_client_updates(upd, clip=float("inf"))
    for k in upd:
        assert np.array_equal(np.asarray(out_inf[k]), np.asarray(upd[k]))


# ------------------------------------------------------------------- config
def test_dpconfig_validation_and_seed_derivation():
    dp.DPConfig(clip=1.0, sigma=0.5).validate()
    dp.DPConfig().validate()                        # identity config is fine
    with pytest.raises(ValueError, match="clip must be positive"):
        dp.DPConfig(clip=0.0).validate()
    with pytest.raises(ValueError, match="sigma must be >= 0"):
        dp.DPConfig(clip=1.0, sigma=-0.1).validate()
    with pytest.raises(ValueError, match="requires a finite dp.clip"):
        dp.DPConfig(sigma=0.5).validate()           # noise without clip
    with pytest.raises(ValueError, match="delta must be in"):
        dp.DPConfig(clip=1.0, sigma=0.5, delta=0.0).validate()
    c = dp.DPConfig(clip=1.0, sigma=0.5)
    s1 = c.client_seeds(3, [1, 5, 9])
    assert s1.dtype == np.uint32 and len(set(s1.tolist())) == 3
    assert np.array_equal(s1, c.client_seeds(3, [1, 5, 9]))   # pure function
    assert not np.array_equal(s1, c.client_seeds(4, [1, 5, 9]))  # per round
    assert c.sigma_client(4) == pytest.approx(0.25)
    with pytest.raises(ValueError, match="cannot carry DP noise"):
        dp.reject_codec_with_noise("int8", 0.5)
    dp.reject_codec_with_noise("int8", 0.0)         # no noise: any codec


# ---------------------------------------------- exact noise+mask composition
def _scatter64(idx, vals, padded):
    out = np.zeros(padded, np.float64)
    np.add.at(out, np.asarray(idx).ravel(),
              np.asarray(vals, np.float64).ravel())
    return out


@pytest.mark.parametrize("seed", range(8))
def test_noise_and_masks_compose_exactly_on_grid(seed):
    """The tentpole property: with gradients, masks AND noise all on the
    f32-exact 2^-24 grid (and per-slot sums < 1), every f32 add in the
    encode is exact, so the server-visible sum equals the released common-
    support sum plus exactly the injected noise — over the full cohort and
    over any survivor subset >= t with Bonawitz mask recovery."""
    C, n, k = 5, 512, 12
    rng = np.random.default_rng(seed)
    # gradients snapped to the grid at |g| ~ 0.03: every slot value
    # g + mask + noise stays < 1 (< 2^24 grid units), the f32 exactness bound
    g = jnp.asarray(np.round(rng.normal(size=(C, n)) * 2 ** 19) * GRID,
                    jnp.float32)
    r = jnp.zeros_like(g)
    # p=-0.5, q=1.0 keeps mask values on the grid with |mask| <= 0.5
    sa = SecureAggConfig(mask_ratio=0.25, p=-0.5, q=1.0, seed=seed)
    km = sa.k_mask_for(n, C)
    pk, ps = streams.pair_seed_matrix(sa, list(range(C)), round_t=seed)
    dpc = dp.DPConfig(clip=1.0, sigma=0.5, delta=1e-5, seed=seed)
    sigma_c = 0.01                                  # |noise| < ~0.07 at 7 sd
    dp_seeds = jnp.asarray(dpc.client_seeds(seed, list(range(C))))
    sup_seed = dpc.support_seed(seed)
    enc = dict(k=k, nb=1, m=n, size=n, pair_seeds=pk, pair_signs=ps,
               k_mask=km, mask_p=sa.p, mask_q=sa.q, leaf_id=0)
    st_n, nr_n = streams.encode_leaf_batch(
        g, r, dp_sigma=sigma_c, dp_seeds=dp_seeds,
        dp_support_seed=sup_seed, **enc)
    # the release support is the round's PUBLIC common stream: the k data
    # slots of every client carry the same (seed, round, leaf)-derived
    # indices, independent of the gradients
    sup = np.asarray(dp.common_support(sup_seed, 1, k, n, 0)).ravel()
    idx = np.asarray(st_n.indices).reshape(C, -1)
    for c in range(C):
        assert np.array_equal(idx[c, :k], sup)
    # residuals keep the untransmitted mass: g with support coords zeroed
    exp_res = np.asarray(g, np.float64).copy()
    exp_res[:, sup] = 0.0
    assert np.array_equal(np.asarray(nr_n, np.float64), exp_res)
    # every stream value is still an exact grid multiple: no f32 add rounded
    units = np.asarray(st_n.values, np.float64) / GRID
    assert np.array_equal(units, np.round(units)), "f32 encode left the grid"
    # oracle noise: the per-(round, client) stream on the k released slots,
    # zero on the mask-only slots (masks cancel pairwise; noise there would
    # add error without privacy)
    noise_k = np.asarray(kref.dp_noise_stream_ref(
        kref.fold_leaf_seed(dp_seeds, 0), 1, k, sigma=sigma_c), np.float64)
    noise = np.zeros((C, idx.shape[1]), np.float64)
    noise[:, :k] = noise_k.reshape(C, k)
    assert float(np.abs(noise).max()) > 0.0
    # --- full cohort: masks cancel exactly under the noise ---------------
    transmitted = (np.asarray(g, np.float64)
                   - np.asarray(nr_n, np.float64))   # per-client g-parts
    full = _scatter64(st_n.indices, st_n.values, n)
    noise_sum = _scatter64(st_n.indices, noise, n)
    assert np.array_equal(full, transmitted.sum(0) + noise_sum)
    # --- survivor subsets >= t, with mask recovery -----------------------
    t = sa.t_for(C)
    for dead in ((), (1,), (0, 3)):
        alive_np = np.array([c not in dead for c in range(C)])
        assert int(alive_np.sum()) >= t
        alive = jnp.asarray(alive_np)
        # oracle: survivors' streams minus their reconstructed masks toward
        # the dead (recomputed independently from the seed matrix rows)
        m_idx, m_vals = streams.mask_streams_rows(
            pk, ps, 1, km, n, p=sa.p, q=sa.q, leaf_id=0)
        mi = np.asarray(m_idx).reshape(C, C, km)     # [client, peer, k_mask]
        mv = np.asarray(m_vals, np.float64).reshape(C, C, km)
        oracle = np.zeros(n, np.float64)
        for c in range(C):
            if not alive_np[c]:
                continue
            oracle += _scatter64(st_n.indices[c], st_n.values[c], n)
            for j in dead:
                oracle -= _scatter64(mi[c, j], mv[c, j], n)
        dec = np.asarray(streams.decode_leaf_batch(
            st_n, nb=1, m=n, size=n, alive=alive, pair_seeds=pk,
            pair_signs=ps, k_mask=km, mask_p=sa.p, mask_q=sa.q, leaf_id=0),
            np.float64)
        # the survivors' pairwise masks cancel exactly in the f64 oracle:
        # what remains is exactly their g-parts plus their noise
        surv_noise = sum(_scatter64(st_n.indices[c], noise[c], n)
                         for c in range(C) if alive_np[c])
        expected = transmitted[alive_np].sum(0) + surv_noise
        assert np.array_equal(oracle, expected)
        # and the real f32 decode matches the oracle to scatter-order ulps
        np.testing.assert_allclose(dec, oracle, rtol=0, atol=2 ** -20)


def test_dp_release_support_is_public_and_data_independent():
    """Under noise, the transmitted index support is a pure function of
    (dp seed, round, leaf) — two encodes of completely different gradients
    transmit the SAME indices (no data-dependent index leakage), and the
    support changes with the round seed."""
    C, n, k = 4, 256, 8
    rng = np.random.default_rng(7)
    sa = SecureAggConfig(mask_ratio=0.25, p=-0.5, q=1.0, seed=7)
    km = sa.k_mask_for(n, C)
    pk, ps = streams.pair_seed_matrix(sa, list(range(C)), round_t=0)
    dpc = dp.DPConfig(clip=1.0, sigma=0.5, seed=7)
    dp_seeds = jnp.asarray(dpc.client_seeds(0, list(range(C))))
    enc = dict(k=k, nb=1, m=n, size=n, pair_seeds=pk, pair_signs=ps,
               k_mask=km, mask_p=sa.p, mask_q=sa.q, leaf_id=0,
               dp_sigma=0.01, dp_seeds=dp_seeds)
    g1 = jnp.asarray(np.round(rng.normal(size=(C, n)) * 2 ** 19) * GRID,
                     jnp.float32)
    g2 = jnp.asarray(np.round(rng.normal(size=(C, n)) * 2 ** 19) * GRID,
                     jnp.float32)
    z = jnp.zeros_like(g1)
    s1, _ = streams.encode_leaf_batch(
        g1, z, dp_support_seed=dpc.support_seed(0), **enc)
    s2, _ = streams.encode_leaf_batch(
        g2, z, dp_support_seed=dpc.support_seed(0), **enc)
    assert np.array_equal(np.asarray(s1.indices), np.asarray(s2.indices))
    s3, _ = streams.encode_leaf_batch(
        g1, z, dp_support_seed=dpc.support_seed(1), **enc)
    i1 = np.asarray(s1.indices).reshape(C, -1)[:, :k]
    i3 = np.asarray(s3.indices).reshape(C, -1)[:, :k]
    assert not np.array_equal(i1, i3)           # fresh support each round


def test_emitted_stream_norm_bounded_by_clip_under_error_feedback():
    """The high-severity review point: error-feedback residuals accumulate
    untransmitted mass, so clipping the fresh delta alone does NOT bound
    what a client emits. The engine clips the encoder input residual+delta
    (and re-seeds the residual from the clipped accumulator, the fedavg
    wiring) — the emitted stream's L2 stays <= S every round."""
    C, n, k = 3, 256, 8
    S = 1.0
    rng = np.random.default_rng(11)
    sa = SecureAggConfig(mask_ratio=0.25, p=-0.5, q=1.0, seed=11)
    km = sa.k_mask_for(n, C)
    dpc = dp.DPConfig(clip=S, sigma=0.5, seed=11)
    res = np.zeros((C, n), np.float32)
    for rnd in range(4):
        delta = (np.round(rng.normal(size=(C, n)) * 2 ** 21) * GRID
                 ).astype(np.float32) * 3.0     # deltas far above the bound
        pk, ps = streams.pair_seed_matrix(sa, list(range(C)), round_t=rnd)
        acc = jnp.asarray(delta) + jnp.asarray(res)
        clipped = dp.clip_client_updates({"w": acc}, clip=S)["w"]
        dp_seeds = jnp.asarray(dpc.client_seeds(rnd, list(range(C))))
        st, nr = streams.encode_leaf_batch(
            clipped, jnp.zeros_like(clipped), k=k, nb=1, m=n, size=n,
            pair_seeds=pk, pair_signs=ps, k_mask=km, mask_p=sa.p,
            mask_q=sa.q, leaf_id=0, dp_sigma=0.01, dp_seeds=dp_seeds,
            dp_support_seed=dpc.support_seed(rnd))
        emitted = np.asarray(clipped, np.float64) - np.asarray(nr, np.float64)
        norms = np.sqrt((emitted ** 2).sum(1))
        assert norms.max() <= S * (1 + 1e-6)
        res = np.asarray(nr)
    # counterfactual (pure numpy): clip the fresh delta ALONE and emit
    # top-k(residual + delta). A uniform delta of norm S (clip is a no-op)
    # parks its mass in the residual until the emitted top-k concentrates
    # more than S — the sensitivity breach the engine's clipping prevents.
    n2, k2 = 64, 16
    bad_res = np.zeros(n2)
    bad_violated = False
    for _ in range(6):
        d = np.full(n2, 1.0 / math.sqrt(n2))   # ||d||_2 == S == 1 exactly
        acc2 = bad_res + d
        order = np.argsort(-np.abs(acc2))[:k2]
        if math.sqrt(float((acc2[order] ** 2).sum())) > 1.0 + 1e-9:
            bad_violated = True
        acc2[order] = 0.0
        bad_res = acc2
    assert bad_violated, "counterexample should breach S within 6 rounds"


def test_run_round_rejects_nonuniform_weights_with_dp():
    """Library-level guard (not just SimConfig): client_weights != 1 under
    DP would scale a stream past the clip bound S."""
    from repro.core.fedavg import init_state, run_round
    from repro.core.types import FedConfig

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] - y) ** 2)

    key = jax.random.key(0)
    x = jax.random.normal(key, (2, 4, 8))
    batches = {c: (x, jnp.ones((2, 4, 1))) for c in range(3)}
    fed = FedConfig(n_clients=3, clients_per_round=3, local_steps=2,
                    local_batch=4, local_lr=0.1, rounds=1)
    st = init_state({"w": jnp.zeros((8, 1))}, fed)
    thgs = THGSConfig(s0=0.5, alpha=0.9, s_min=0.1)
    sa = SecureAggConfig(mask_ratio=0.25)
    dpc = dp.DPConfig(clip=1.0, sigma=0.5)
    with pytest.raises(ValueError, match="uniform client weights"):
        run_round(st, batches, loss_fn, fed, thgs, sa,
                  client_weights={1: 2.0}, dp=dpc)
    # uniform weights (explicit 1.0) pass the guard
    run_round(st, batches, loss_fn, fed, thgs, sa,
              client_weights={1: 1.0}, dp=dpc)


# --------------------------------------------------- sigma=0 == plain secagg
_DP_TINY = SimConfig(
    name="dp_tiny", partition="noniid", noniid_k=4, n_clients=5,
    clients_per_round=3, rounds=4, n_train=300, n_test=120,
    local_steps=2, local_batch=8, eval_every=1,
    thgs=THGSConfig(s0=0.1, alpha=0.9, s_min=0.02),
    sa=SecureAggConfig(mask_ratio=0.02), dropout_rate=0.25, seed=3)


def test_sim_sigma0_clip_inf_bit_identical_to_secagg():
    """A DPConfig() (sigma=0, clip=inf) run is bit-identical to dp=None —
    params, losses, accuracies and the full CommLedger (same style as the
    tau=0 async and tree==flat guarantees)."""
    s0 = Simulation(_DP_TINY)
    r0 = s0.run(resume=False)
    s1 = Simulation(_DP_TINY.replace(dp=dp.DPConfig()))
    r1 = s1.run(resume=False)
    for a, b in zip(jax.tree_util.tree_leaves(s0.state.params),
                    jax.tree_util.tree_leaves(s1.state.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert r0.losses == r1.losses
    assert r0.accuracies == r1.accuracies
    assert r0.ledger.entries == r1.ledger.entries
    assert "privacy" not in r0.ledger.summary()
    assert "privacy" not in r1.ledger.summary()     # inactive dp: no block


def test_sim_dp_run_has_privacy_ledger_and_same_wire_bits():
    """Noised DP perturbs values only: the bit accounting is identical to the
    same run without DP, and the ledger gains a finite composed epsilon."""
    cfg = _DP_TINY.replace(dp=dp.DPConfig(clip=1.0, sigma=0.6, delta=1e-5))
    r_dp = Simulation(cfg).run(resume=False)
    r_plain = Simulation(_DP_TINY).run(resume=False)
    pb = costs.PAPER_BITS
    # round 0 starts from identical params, so its slot counts match exactly:
    # the noise itself costs zero wire bits (it rides existing stream slots).
    # Later rounds' top-k counts drift — Eq. 2's schedule adapts to the loss
    # trajectory, which the noised aggregate shifts — but the mask plane and
    # the secagg control traffic stay bit-for-bit identical throughout.
    e0_dp, e0_pl = r_dp.ledger.entries[0], r_plain.ledger.entries[0]
    assert e0_dp.ks == e0_pl.ks
    assert e0_dp.upload_bits(pb) == e0_pl.upload_bits(pb)
    for e_dp, e_pl in zip(r_dp.ledger.entries, r_plain.ledger.entries):
        assert e_dp.k_masks == e_pl.k_masks
        assert e_dp.share_upload_bits(pb) == e_pl.share_upload_bits(pb)
        assert e_dp.dp_sigma == 0.6 and e_dp.dp_clip == 1.0
        assert e_dp.dp
    priv = r_dp.ledger.privacy()
    assert priv is not None
    assert math.isfinite(priv["epsilon"]) and priv["epsilon"] > 0
    assert priv["delta"] == 1e-5
    assert priv["rounds"] == cfg.rounds
    assert len(priv["per_round"]) == cfg.rounds
    # survivor-aware z_eff never exceeds the configured z
    assert all(0 < p["z_eff"] <= 0.6 + 1e-12 for p in priv["per_round"])
    assert "privacy" in r_dp.ledger.summary()


def test_sim_dp_resume_replays_noise_bit_identically(tmp_path):
    """Kill mid-horizon, resume from the checkpoint: the per-round noise
    seeds are a pure function of (dp seed, round, client), so the resumed
    run's params are bit-identical to the uninterrupted run's."""
    cfg = _DP_TINY.replace(
        dp=dp.DPConfig(clip=1.0, sigma=0.6, delta=1e-5),
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=1)

    class _Killed(Exception):
        pass

    def die_after_round_1(r, info):
        if r == 1:
            raise _Killed

    with pytest.raises(_Killed):
        Simulation(cfg).run(hooks=[die_after_round_1])
    s_res = Simulation(cfg)
    r_res = s_res.run()
    s_full = Simulation(cfg.replace(ckpt_dir=None, ckpt_every=0))
    r_full = s_full.run(resume=False)
    for a, b in zip(jax.tree_util.tree_leaves(s_res.state.params),
                    jax.tree_util.tree_leaves(s_full.state.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert r_res.ledger.entries == r_full.ledger.entries
    assert r_res.ledger.privacy() == r_full.ledger.privacy()


# --------------------------------------------------------------- accountant
def test_accountant_values_and_monotonicity():
    eps1 = dp.compose_epsilon([1.0], 1e-5)
    assert 3.0 < eps1 < 6.0                  # z=1, delta=1e-5: ~5.3 on grid
    assert dp.round_epsilon(1.0, 1e-5) == eps1
    eps8 = dp.compose_epsilon([1.0] * 8, 1e-5)
    assert eps8 > eps1                       # more rounds cost more
    assert dp.compose_epsilon([2.0], 1e-5) < eps1    # more noise costs less
    assert dp.compose_epsilon([0.0], 1e-5) == math.inf   # no noise: not DP
    assert dp.compose_epsilon([1.0, 0.0], 1e-5) == math.inf
    assert dp.compose_epsilon([], 1e-5) == 0.0
    with pytest.raises(ValueError):
        dp.compose_epsilon([1.0], 0.0)
    assert dp.gaussian_rdp(1.0, 2.0) == 1.0
    assert dp.gaussian_rdp(0.0, 2.0) == math.inf


def test_ledger_privacy_survivor_aware_and_json_roundtrip(tmp_path):
    led = CommLedger()
    for t, surv in enumerate((4, 3, 4)):
        led.record(costs.round_record(
            t, model_size=1000, ks=[8], k_masks=[2], n_clients=4,
            n_survivors=surv, threshold=3, dp_clip=1.0, dp_sigma=0.8,
            dp_delta=1e-5))
    e = led.entries[1]
    assert e.dp_z_eff() == pytest.approx(0.8 * math.sqrt(3 / 4))
    priv = led.privacy()
    assert priv["noise_multiplier"] == 0.8 and priv["clip"] == 1.0
    # dropout rounds realize less sum-noise -> worse (larger) epsilon than
    # the full-cohort composition of the same z
    full_eps = dp.compose_epsilon([0.8] * 3, 1e-5)
    assert priv["epsilon"] > full_eps
    path = led.to_json(str(tmp_path / "led.json"))
    data = json.loads(open(path).read())["ledger"]
    assert data["privacy"]["epsilon"] == pytest.approx(priv["epsilon"])
    # entries -> ledger roundtrip keeps the dp facts
    led2 = CommLedger.from_entry_dicts(data["entries"])
    assert led2.privacy()["epsilon"] == pytest.approx(priv["epsilon"])
    assert [e.dp_sigma for e in led2.entries] == [0.8] * 3


# ------------------------------------------------------------ config gating
def test_simconfig_dp_rejections():
    base = _DP_TINY.replace(dp=dp.DPConfig(clip=1.0, sigma=0.5))
    base.validate()
    with pytest.raises(ValueError, match="dp requires THGS"):
        base.replace(thgs=None, sa=SecureAggConfig(enabled=False)).validate()
    with pytest.raises(ValueError, match="cannot carry DP noise"):
        base.replace(codec="int8",
                     sa=SecureAggConfig(enabled=False)).validate()
    with pytest.raises(ValueError, match="mode='async'"):
        base.replace(mode="async", dropout_rate=0.0,
                     sa=SecureAggConfig(enabled=False)).validate()
    with pytest.raises(ValueError, match="weight_by_data_count"):
        base.replace(weight_by_data_count=True).validate()
    with pytest.raises(ValueError, match="finite dp.clip"):
        base.replace(dp=dp.DPConfig(sigma=0.5)).validate()
    # clip-only DP (no noise) is allowed and composes to epsilon=inf
    base.replace(dp=dp.DPConfig(clip=1.0)).validate()
