"""FL loop integration: FedAvg/FedProx + THGS + secure agg converge (paper §5)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs
from repro.core.fedavg import init_state, run_round
from repro.core.types import FedConfig, SecureAggConfig, THGSConfig


def _linreg_setup(key, n_clients=4, dim=5):
    true_w = jnp.linspace(1.0, 5.0, dim).reshape(dim, 1)

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    def make_batches(r):
        out = {}
        for c in range(n_clients):
            k = jax.random.fold_in(key, r * 100 + c)
            x = jax.random.normal(k, (3, 8, dim))
            out[c] = (x, x @ true_w + 0.5)
        return out

    params = {"w": jnp.zeros((dim, 1)), "b": jnp.zeros((1,))}
    return params, loss_fn, make_batches, true_w


def _run(thgs, sa, algorithm="fedavg", rounds=12, dim=5, lr=0.05):
    key = jax.random.key(0)
    params, loss_fn, make_batches, true_w = _linreg_setup(key, dim=dim)
    fed = FedConfig(n_clients=4, clients_per_round=4, local_steps=3,
                    local_batch=8, local_lr=lr, rounds=rounds,
                    algorithm=algorithm, prox_mu=0.01)
    st = init_state(params, fed)
    for r in range(rounds):
        st = run_round(st, make_batches(r), loss_fn, fed, thgs, sa)
    err = float(jnp.max(jnp.abs(st.params["w"] - true_w)))
    return st, err


def test_fedavg_dense_converges():
    st, err = _run(None, SecureAggConfig(enabled=False))
    assert err < 0.3


def test_fedavg_dense_secure_agg_matches_plain():
    st1, _ = _run(None, SecureAggConfig(enabled=False))
    st2, _ = _run(None, SecureAggConfig(enabled=True))
    np.testing.assert_allclose(np.asarray(st1.params["w"]),
                               np.asarray(st2.params["w"]), rtol=1e-3,
                               atol=1e-4)


def test_thgs_secure_converges_and_compresses():
    # compression needs a model big enough that k << size (paper's regime)
    thgs = THGSConfig(s0=0.2, alpha=0.9, s_min=0.05, time_varying=True)
    st, err = _run(thgs, SecureAggConfig(mask_ratio=0.02), rounds=80, dim=400, lr=3e-3)
    assert err < 3.0  # progress from ||w*||_inf = 5 under strong sparsity
    rec = st.comm_log[-1]
    assert rec.upload_bits < rec.dense_upload_bits  # compressed uploads


def test_fedprox_converges():
    st, err = _run(None, SecureAggConfig(enabled=False),
                   algorithm="fedprox")
    assert err < 0.4


def test_comm_cost_eq6():
    bits = costs.PAPER_BITS
    # Eq. 6: m*s*96 bits per sparse upload element
    assert bits.sparse_bits(1000) == 1000 * 96
    assert bits.dense_bits(1000) == 1000 * 64
    rec = costs.round_record(0, 10_000, ks=[100], k_masks=[10], n_clients=10)
    assert rec.upload_bits == 10 * (100 + 9 * 10) * 96
    assert rec.compression > 1
