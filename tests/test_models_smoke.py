"""Per-arch smoke: REDUCED variant (<=2 layers, d<=512, <=4 experts) — one
forward/train step on CPU, asserting shapes + no NaNs; plus serving paths."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as tf

KEY = jax.random.key(0)
B, T = 2, 32


def _batch(cfg):
    batch = {"labels": jax.random.randint(KEY, (B, T), 0, cfg.vocab)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(KEY, (B, T, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", configs.all_archs())
def test_train_step(arch):
    cfg = configs.reduced(configs.get(arch))
    params = tf.init_params(cfg, KEY)
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: tf.train_loss(p, cfg, b)))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: NaN grad at {path}"


@pytest.mark.parametrize("arch", configs.all_archs())
def test_decode_and_prefill_shapes(arch):
    cfg = configs.reduced(configs.get(arch))
    params = tf.init_params(cfg, KEY)
    batch = _batch(cfg)
    inp = batch.get("tokens", batch.get("frames"))
    logits, state = jax.jit(lambda p, x: tf.prefill(
        p, cfg, x, 64, image_embeds=batch.get("image_embeds")))(params, inp)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    if not cfg.supports_decode:
        assert state is None  # encoder-only: no decode state
        return
    tok = jnp.zeros((B, 1), jnp.int32)
    st0 = tf.init_decode_state(cfg, B, 64)
    lg, st1 = jax.jit(lambda p, t, s: tf.decode_step(p, cfg, t, s))(
        params, tok, st0)
    assert lg.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))


@pytest.mark.parametrize("arch", ["yi_6b", "deepseek_moe_16b", "zamba2_7b",
                                  "xlstm_125m"])
def test_prefill_decode_consistency(arch):
    """greedy decode after prefill == teacher-forced forward argmax."""
    cfg = configs.reduced(configs.get(arch))
    params = tf.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.fold_in(KEY, 7), (1, 16), 0,
                              cfg.vocab)
    # full forward logits at last position
    h = tf.embed_tokens(params, cfg, toks)
    hidden, _ = tf.forward(params, cfg, h)
    from repro.models.layers import apply_norm  # noqa: F401
    full_logits = hidden[:, -1] @ tf.lm_head_weight(params, cfg)
    # prefill on first 15, then decode token 16
    lg15, state = tf.prefill(params, cfg, toks[:, :15], cache_len=32)
    lg, state = tf.decode_step(params, cfg, toks[:, 15:16], state)
    assert jnp.allclose(lg[:, 0], full_logits, rtol=2e-2, atol=2e-3), (
        f"{arch}: prefill+decode diverges from full forward")


def test_long_context_variant_sets_window():
    cfg = configs.get("yi_6b")
    assert cfg.window is None
    assert cfg.long_context_variant().window == 8192
    # ssm archs unchanged
    z = configs.get("xlstm_125m")
    assert z.long_context_variant().window is None


def test_encoder_only_skips():
    cfg = configs.get("hubert_xlarge")
    assert not cfg.supports_shape("decode_32k")
    assert not cfg.supports_shape("long_500k")
    assert cfg.supports_shape("train_4k")
