"""repro.bench: BENCH_*.json schema, the regression gate, and the committed
baselines at the repo root."""
import json
import os

import pytest

from repro.bench import schema

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _doc(entries, suite="round", quick=True):
    return schema.make_doc(entries, suite=suite, quick=quick)


def _entry(name, us, reps=2):
    return {"name": name, "us_per_call": us, "reps": reps, "derived": "x"}


def test_make_doc_validates():
    doc = _doc([_entry("round/serial_c8", 100.0)])
    assert schema.validate_doc(doc) == []
    combined = schema.make_doc(
        None, suites={"round": [_entry("round/serial_c8", 1.0)],
                      "agg": [_entry("agg/loop", 2.0)]})
    assert schema.validate_doc(combined) == []
    assert [e["name"] for e in schema.iter_entries(combined)] == [
        "round/serial_c8", "agg/loop"]


def test_validate_rejects_malformed():
    assert schema.validate_doc({"schema": "nope"})
    doc = _doc([_entry("a", 1.0), _entry("a", 2.0)])      # duplicate name
    assert any("duplicate" in e for e in schema.validate_doc(doc))
    doc = _doc([{"us_per_call": 1.0}])                    # nameless entry
    assert any("without a name" in e for e in schema.validate_doc(doc))
    doc = _doc([_entry("a", -1.0)])                       # negative time
    assert any("us_per_call" in e for e in schema.validate_doc(doc))
    assert any("non-empty" in e for e in schema.validate_doc(_doc([])))


def test_gate_passes_within_threshold():
    base = _doc([_entry("round/serial_c8", 100.0)])
    cur = _doc([_entry("round/serial_c8", 299.0)])
    failures, compared = schema.gate_compare(cur, [base], max_slowdown=3.0)
    assert compared == 1 and failures == []


def test_gate_fails_beyond_threshold():
    base = _doc([_entry("round/serial_c8", 100.0)])
    cur = _doc([_entry("round/serial_c8", 301.0)])
    failures, compared = schema.gate_compare(cur, [base], max_slowdown=3.0)
    assert compared == 1 and len(failures) == 1
    assert "round/serial_c8" in failures[0]


def test_gate_skips_info_rows_and_noise_floor():
    base = _doc([_entry("round/speedup", 0.0),    # info row
                 _entry("agg/tiny", 5.0)])        # below the noise floor
    cur = _doc([_entry("round/speedup", 0.0),
                _entry("agg/tiny", 500.0)])
    failures, compared = schema.gate_compare(cur, [base], min_us=20.0)
    assert failures == []
    assert compared == 1   # only the floored entry was comparable


def test_gate_unmatched_names_do_not_compare():
    """Quick and full runs encode sizes in names -> no cross-mode gating."""
    base = _doc([_entry("agg/loop_c32_n65536", 100.0)])
    cur = _doc([_entry("agg/loop_c8_n16384", 1e9)])
    failures, compared = schema.gate_compare(cur, [base])
    assert failures == [] and compared == 0


@pytest.mark.parametrize("name", ["BENCH_round.json", "BENCH_agg.json",
                                  "BENCH_cohort.json", "BENCH_serve.json"])
def test_committed_baselines_are_valid(name):
    """The perf-trajectory baselines at the repo root stay schema-valid."""
    path = os.path.join(ROOT, name)
    assert os.path.exists(path), f"missing committed baseline {name}"
    with open(path) as f:
        doc = json.load(f)
    assert schema.validate_doc(doc) == []
    assert doc["quick"], "committed baselines must be --quick runs (the CI " \
                         "gate compares a --quick run against them)"
    # the suite must carry at least one gateable (non-info) timing entry
    assert any(e["us_per_call"] > 0 for e in schema.iter_entries(doc))


def test_cli_gate_roundtrip(tmp_path):
    """--gate exit codes: 0 in-budget, 1 on regression, 1 on vacuous gate."""
    from repro.bench.__main__ import main

    base = tmp_path / "base.json"
    base.write_text(json.dumps(_doc([_entry("round/serial_c8", 100.0)])))
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps(_doc([_entry("round/serial_c8", 120.0)])))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(_doc([_entry("round/serial_c8", 1e6)])))
    vac = tmp_path / "vac.json"
    vac.write_text(json.dumps(_doc([_entry("round/other", 1.0)])))
    argv = ["--gate", None, "--baseline", str(base)]
    for path, rc in ((ok, 0), (bad, 1), (vac, 1)):
        argv[1] = str(path)
        assert main(argv) == rc


def test_run_suite_unknown_raises():
    from repro.bench import run_suite

    with pytest.raises(KeyError):
        run_suite("nope")


def test_measure_returns_min_of_reps(monkeypatch):
    """timing.measure is min-of-single-rep wall clock: a scripted clock with
    one slow rep must not move the result (the flake the 3x gate kept
    tripping on before min-of-reps)."""
    from repro.bench import timing

    # perf_counter pairs per timed rep -> durations 100us, 10us, 50us; the
    # warmup call takes no clock readings (time_us(warmup=0) reads 2/rep)
    ticks = iter([0.0, 100e-6, 1.0, 1.0 + 10e-6, 2.0, 2.0 + 50e-6])
    monkeypatch.setattr(timing.time, "perf_counter", lambda: next(ticks))
    calls = []
    us = timing.measure(lambda: calls.append(1), reps=3, warmup=1)
    assert us == pytest.approx(10.0)
    assert len(calls) == 4  # 1 warmup + 3 timed reps


def test_all_json_suites_time_with_min_of_reps():
    """Every BENCH suite must time through timing.measure (min-of-reps) —
    mean-of-reps entries trip the CI gate on a single scheduler stall
    (ISSUE 7 satellite; PR 6 hit this on the agg micro-entries). Enforced
    by repro.lint RPL002 (the AST check that replaced the source greps that
    used to live here)."""
    import importlib

    from repro import lint
    from repro.bench import JSON_SUITES

    for name, (mod_name, _) in JSON_SUITES.items():
        mod = importlib.import_module(mod_name)
        findings = lint.lint_file(mod.__file__, select={"RPL002"})
        assert [f for f in findings if not f.suppressed] == [], (
            f"suite {name}: " + "; ".join(f.message for f in findings))


def test_rpl002_is_not_vacuous_on_suite_paths():
    """The RPL002 scope must actually cover the suite modules: a time_us
    call at a suite-shaped path has to flag (guards the check against a
    path-scoping regression silently blessing every suite)."""
    from repro import lint

    bad = (
        "from repro.bench.timing import time_us\n"
        "def entries(quick=False):\n"
        "    return [('x', time_us(lambda: None, reps=2))]\n"
    )
    findings = lint.lint_source(
        bad, path="src/repro/bench/fake_bench.py", select={"RPL002"})
    assert any("time_us" in f.message for f in findings)
