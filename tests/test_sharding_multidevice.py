"""Multi-device sharding behaviour, run in a subprocess with 8 fake CPU devices
(the main test process must keep seeing exactly 1 device).

The client-parallel round (full-manual shard_map over a 1-D clients mesh,
DESIGN.md §11) has no such version floor and is covered on every runtime by
tests/test_client_sharded_round.py; only the partial-manual FL mesh step
below needs the jaxlib >= 0.5 SPMD partitioner.
"""
import json
import os
import subprocess
import sys

import re

import jaxlib
import pytest

# tolerant of pre-release suffixes ('0.5.0rc1'); unparseable -> (0, 0) = skip
_m = re.match(r"(\d+)\.(\d+)", jaxlib.__version__)
_JAXLIB_VERSION = (int(_m.group(1)), int(_m.group(2))) if _m else (0, 0)

SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# force the CPU platform: xla_force_host_platform_device_count only applies to
# it, and probing for a TPU backend first hangs for minutes in this container
os.environ["JAX_PLATFORMS"] = "cpu"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import transformer as tf
from repro.models.sharding import logical_axis_rules
from repro.launch import shardings as shd
from repro.launch.mesh import logical_rules
from repro.launch.train import make_fl_train_step, make_dense_train_step
from repro.core.types import THGSConfig, SecureAggConfig

assert len(jax.devices()) == 8
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = configs.reduced(configs.get("yi_6b"))
key = jax.random.key(0)
params = tf.init_params(cfg, key)
rules = logical_rules(mesh, fed_axis="pod")
pshapes = jax.tree_util.tree_map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
pshard = shd.named(shd.param_specs(pshapes, rules, mesh), mesh)
params = jax.device_put(params, pshard)
B, T = 8, 32
batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab),
         "labels": jax.random.randint(key, (B, T), 0, cfg.vocab)}
batch = jax.device_put(batch, NamedSharding(mesh, P(("pod", "data"), None)))
thgs = THGSConfig(s0=0.1, alpha=0.9, s_min=0.01)
sa = SecureAggConfig(mask_ratio=0.05)
step = make_fl_train_step(cfg, mesh, "pod", thgs, sa, lr=0.05)
res = jax.tree_util.tree_map(
    lambda x: jnp.zeros((2,) + x.shape, jnp.bfloat16), params)
res = jax.device_put(res, NamedSharding(mesh, P("pod")))
with logical_axis_rules(mesh, rules):
    losses = []
    p, r = params, res
    for i in range(3):
        p, r, loss = jax.jit(step)(p, r, batch, jax.random.key(i))
        losses.append(float(loss))
    dstep = jax.jit(make_dense_train_step(cfg, lr=0.05))
    pd, dloss = dstep(params, batch)
finite = all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
             for x in jax.tree_util.tree_leaves(p))
print(json.dumps({"losses": losses, "dense_loss": float(dloss),
                  "finite": finite}))
"""


@pytest.mark.slow
@pytest.mark.skipif(
    _JAXLIB_VERSION < (0, 5),
    reason="partial-manual shard_map with sharding constraints / collectives "
           "inside the manual region aborts jaxlib<0.5's SPMD partitioner "
           "(XLA CHECK 'IsManualSubgroup', uncatchable process abort). Keyed "
           "on the actual jaxlib floor — the previous hasattr(jax, "
           "'shard_map') marker only appears in jax>=0.6 and skipped "
           "working 0.5.x runtimes")
def test_fl_step_on_multipod_mesh():
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", SNIPPET], capture_output=True,
                         text=True, cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["finite"]
    assert res["losses"][-1] < res["losses"][0], res  # FL training makes progress
