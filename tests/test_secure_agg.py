"""Secure aggregation correctness: Eq. 5 semantics under the two-stream encoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep; tier-1 must collect without it
from hypothesis import given, settings, strategies as st

from repro.core.secure_agg import (aggregate_streams, dense_masked_update,
                                   encode_leaf, encode_update)
from repro.core.masks import client_masks
from repro.core.types import SecureAggConfig, THGSConfig, tree_zeros_like

THGS = THGSConfig(s0=0.2, alpha=0.9, s_min=0.05)


def _make_grads(key, n_clients, shape=(30, 10)):
    return {c: {"w": jax.random.normal(jax.random.fold_in(key, c), shape)}
            for c in range(n_clients)}


@given(n_clients=st.integers(2, 5), seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_masked_aggregate_equals_unmasked(n_clients, seed):
    """Server-side sum with masks == sum without masks (masks cancel exactly),
    and equals sum of (acc - residual) per client."""
    key = jax.random.key(seed)
    sa = SecureAggConfig(mask_ratio=0.3, seed=seed)
    parts = list(range(n_clients))
    grads = _make_grads(key, n_clients)
    leaves0 = jax.tree_util.tree_leaves(grads[0])
    ks = [20]

    streams_all, expected = [], jnp.zeros(leaves0[0].size)
    for c in parts:
        res = tree_zeros_like(grads[c])
        streams, new_res = encode_update(grads[c], res, ks, THGS, sa,
                                         client=c, participants=parts,
                                         round_t=3)
        streams_all.append(streams)
        transmitted = (grads[c]["w"] - new_res["w"]).reshape(-1)
        expected = expected + transmitted / n_clients
    agg = aggregate_streams(streams_all, [leaves0[0].shape],
                            [leaves0[0].dtype])
    np.testing.assert_allclose(np.asarray(agg[0].reshape(-1)),
                               np.asarray(expected), rtol=1e-4, atol=1e-5)


def test_single_client_no_mask():
    key = jax.random.key(0)
    sa = SecureAggConfig()
    g = {"w": jax.random.normal(key, (50,))}
    streams, _ = encode_update(g, tree_zeros_like(g), [10], THGS, sa,
                               client=0, participants=[0], round_t=0)
    assert streams[0].k == 10  # no mask slots when alone


def test_mask_positions_transmitted_with_gradient_value():
    """Alg. 2 line 16-17: residual zeroes every transmitted position,
    including mask-support positions below the top-k threshold."""
    key = jax.random.key(1)
    sa = SecureAggConfig(mask_ratio=0.5, seed=9)
    g = jax.random.normal(key, (200,))
    mask = client_masks(sa, 0, [0, 1], 4, 0, 200, sa.k_mask_for(200, 2))
    enc = encode_leaf(g, jnp.zeros_like(g), 5, THGS, mask)
    resid = np.asarray(enc.residual)
    for i in np.asarray(mask.indices):
        assert resid[i] == 0.0


def test_dense_masked_baseline_cancels():
    key = jax.random.key(2)
    sa = SecureAggConfig(seed=3)
    parts = [0, 1, 2]
    updates = {c: jax.random.normal(jax.random.fold_in(key, c), (40,))
               for c in parts}
    total_masked = sum(dense_masked_update(updates[c], sa, c, parts, 0, 0)
                       for c in parts)
    total_plain = sum(updates.values())
    np.testing.assert_allclose(np.asarray(total_masked),
                               np.asarray(total_plain), rtol=1e-4, atol=1e-4)


def test_masked_values_hide_gradient():
    """At mask-support positions the transmitted value != raw gradient."""
    key = jax.random.key(3)
    sa = SecureAggConfig(mask_ratio=0.5, seed=5)
    g = jax.random.normal(key, (100,))
    mask = client_masks(sa, 0, [0, 1], 0, 0, 100, 25)
    enc = encode_leaf(g, jnp.zeros_like(g), 3, THGS, mask)
    idx = np.asarray(enc.stream.indices)
    vals = np.asarray(enc.stream.values)
    graw = np.asarray(g)
    mask_slots = np.arange(3, len(idx))  # slots after the top-k block
    diffs = np.abs(vals[mask_slots] - graw[idx[mask_slots]])
    assert (diffs > 1e-6).mean() > 0.9  # almost all masked (dup slots excepted)
