"""Chunked-parallel forms == recurrent single-step forms (xLSTM, Mamba2 SSD)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMSpec
from repro.models import ssm as sm
from repro.models import xlstm as xm

KEY = jax.random.key(0)


def test_mlstm_chunked_equals_recurrent():
    B, T, d, H = 2, 512, 64, 4
    p = xm.init_mlstm(KEY, d, H, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, d)) * 0.5
    y_par, (C, n, m) = xm.mlstm_forward(p, x, H)
    dh = 2 * d // H
    cache = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
             jnp.zeros((B, H)))
    ys = []
    for t in range(T):
        yt, cache = xm.mlstm_decode_step(p, x[:, t:t + 1], cache, H)
        ys.append(yt)
    y_rec = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               rtol=1e-3, atol=1e-4)
    # final states agree (recurrent is stabilized: unfold exp(m))
    np.testing.assert_allclose(
        np.asarray(C),
        np.asarray(cache[0] * jnp.exp(cache[2])[..., None, None]),
        rtol=1e-3, atol=1e-4)


def test_slstm_chunked_scan_matches_plain():
    B, T, d, H = 2, 256, 32, 4
    p = xm.init_slstm(KEY, d, H, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, d)) * 0.5
    y_two_level, c1 = xm.slstm_forward(p, x, H)      # T > CHUNK_T path
    # plain path via T=CHUNK_T chunks manually
    y_plain, c2 = xm.slstm_forward(p, x[:, :xm.CHUNK_T], H)
    np.testing.assert_allclose(np.asarray(y_two_level[:, :xm.CHUNK_T]),
                               np.asarray(y_plain), rtol=1e-5, atol=1e-5)


def test_ssd_chunked_equals_recurrent():
    B, T, d = 2, 64, 32
    spec = SSMSpec(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16)
    p = sm.init_ssm(KEY, d, spec, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (B, T, d)) * 0.5
    y_par, s_final = sm.ssd_forward(p, x, spec)
    cache = sm.init_cache(B, d, spec, jnp.float32)
    ys = []
    for t in range(T):
        yt, cache = sm.ssd_decode_step(p, x[:, t:t + 1], cache, spec)
        ys.append(yt)
    y_rec = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_final), np.asarray(cache.state),
                               rtol=2e-3, atol=2e-4)


def test_ssd_state_continuation():
    """ssd_forward(x) == ssd_forward(x1) then ssd_forward(x2, init_state)."""
    B, T, d = 1, 32, 16
    spec = SSMSpec(d_state=8, d_conv=4, expand=2, head_dim=8, chunk=8)
    p = sm.init_ssm(KEY, d, spec, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (B, T, d))
    y_full, s_full = sm.ssd_forward(p, x, spec)
    y1, s1 = sm.ssd_forward(p, x[:, :16], spec)
    # NOTE: conv context crosses the boundary; only states are compared here
    y2, s2 = sm.ssd_forward(p, x[:, 16:], spec, init_state=s1)
    np.testing.assert_allclose(np.asarray(y_full[:, :16]), np.asarray(y1),
                               rtol=1e-4, atol=1e-5)
