"""Pallas kernels vs pure-jnp oracles (interpret mode), sweeping shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(42)


@pytest.mark.parametrize("b,t,s,h,hkv,hd", [
    (1, 128, 128, 4, 4, 64),
    (2, 256, 256, 4, 2, 64),
    (1, 256, 256, 8, 1, 128),   # MQA
    (2, 128, 128, 2, 2, 128),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_matches_ref(b, t, s, h, hkv, hd, causal, window):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, h, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, hkv, hd))
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEY, (1, 128, 4, 64)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (1, 128, 2, 64)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (1, 128, 2, 64)).astype(dtype)
    out = ops.flash_attention(q, k, v)
    exp = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=tol, atol=tol)
    assert out.dtype == dtype


@pytest.mark.parametrize("shape", [(100,), (64, 129), (7, 3, 11), (4096,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_thgs_sparsify_matches_ref(shape, dtype):
    g = jax.random.normal(jax.random.fold_in(KEY, 9), shape).astype(dtype)
    r = (jax.random.normal(jax.random.fold_in(KEY, 10), shape) * 0.2).astype(dtype)
    thr = 0.8
    sp, nr = ops.thgs_sparsify(g, r, thr)
    spr, nrr = ref.thgs_sparsify_ref(g, r, thr)
    np.testing.assert_allclose(np.asarray(sp, np.float32),
                               np.asarray(spr, np.float32), rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(np.asarray(nr, np.float32),
                               np.asarray(nrr, np.float32), rtol=1e-2, atol=1e-2)
    # exact split: every position is in exactly one of (sparse, residual)
    both = np.asarray(jnp.abs(sp.astype(jnp.float32)) *
                      jnp.abs(nr.astype(jnp.float32)))
    assert (both < 1e-6).all()


@pytest.mark.parametrize("shape", [(513, 7), (1000,), (128, 128)])
def test_mask_prng_matches_ref_and_cancels(shape):
    g = jax.random.normal(jax.random.fold_in(KEY, 11), shape)
    o_k, m_k = ops.mask_prng_apply(g, seed=1234, sigma=-0.4, sign=1.0)
    o_r, m_r = ref.mask_prng_ref(g, 1234, p=-1.0, q=2.0, sigma=-0.4, sign=1.0)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r), atol=1e-6)
    _, m_neg = ops.mask_prng_apply(g, seed=1234, sigma=-0.4, sign=-1.0)
    assert float(jnp.max(jnp.abs(m_k + m_neg))) == 0.0


@pytest.mark.parametrize("n,size", [(100, 1000), (700, 257), (2048, 100_000),
                                    (5, 64)])
def test_stream_scatter_add_matches_ref(n, size):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 20))
    # include duplicates, the -1 padding sentinel, and out-of-range indices
    idx = jax.random.randint(k1, (n,), -2, size + 3)
    val = jax.random.normal(k2, (n,))
    out = ops.stream_scatter_add(idx, val, size=size)
    exp = ref.stream_scatter_add_ref(idx, val, size)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


def test_stream_scatter_add_duplicates_accumulate():
    idx = jnp.array([3, 3, 3, 0, 9], jnp.int32)
    val = jnp.array([1.0, 2.0, 4.0, 5.0, -1.0])
    out = ops.stream_scatter_add(idx, val, size=10)
    assert float(out[3]) == 7.0 and float(out[0]) == 5.0
    assert float(out[9]) == -1.0 and float(out.sum()) == 11.0


def test_mask_prng_support_fraction():
    g = jnp.zeros((100_000,))
    _, m = ops.mask_prng_apply(g, seed=7, sigma=-0.5, sign=1.0)
    frac = float(jnp.mean(m != 0))
    assert abs(frac - 0.25) < 0.02  # (sigma - p)/q = 0.25
