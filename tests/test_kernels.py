"""Pallas kernels vs pure-jnp oracles (interpret mode), sweeping shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(42)


@pytest.mark.parametrize("b,t,s,h,hkv,hd", [
    (1, 128, 128, 4, 4, 64),
    (2, 256, 256, 4, 2, 64),
    (1, 256, 256, 8, 1, 128),   # MQA
    (2, 128, 128, 2, 2, 128),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_matches_ref(b, t, s, h, hkv, hd, causal, window):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, h, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, hkv, hd))
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEY, (1, 128, 4, 64)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (1, 128, 2, 64)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (1, 128, 2, 64)).astype(dtype)
    out = ops.flash_attention(q, k, v)
    exp = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=tol, atol=tol)
    assert out.dtype == dtype


@pytest.mark.parametrize("shape", [(100,), (64, 129), (7, 3, 11), (4096,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_thgs_sparsify_matches_ref(shape, dtype):
    g = jax.random.normal(jax.random.fold_in(KEY, 9), shape).astype(dtype)
    r = (jax.random.normal(jax.random.fold_in(KEY, 10), shape) * 0.2).astype(dtype)
    thr = 0.8
    sp, nr = ops.thgs_sparsify(g, r, thr)
    spr, nrr = ref.thgs_sparsify_ref(g, r, thr)
    np.testing.assert_allclose(np.asarray(sp, np.float32),
                               np.asarray(spr, np.float32), rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(np.asarray(nr, np.float32),
                               np.asarray(nrr, np.float32), rtol=1e-2, atol=1e-2)
    # exact split: every position is in exactly one of (sparse, residual)
    both = np.asarray(jnp.abs(sp.astype(jnp.float32)) *
                      jnp.abs(nr.astype(jnp.float32)))
    assert (both < 1e-6).all()


@pytest.mark.parametrize("shape", [(513, 7), (1000,), (128, 128)])
def test_mask_prng_matches_ref_and_cancels(shape):
    g = jax.random.normal(jax.random.fold_in(KEY, 11), shape)
    o_k, m_k = ops.mask_prng_apply(g, seed=1234, sigma=-0.4, sign=1.0)
    o_r, m_r = ref.mask_prng_ref(g, 1234, p=-1.0, q=2.0, sigma=-0.4, sign=1.0)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r), atol=1e-6)
    _, m_neg = ops.mask_prng_apply(g, seed=1234, sigma=-0.4, sign=-1.0)
    assert float(jnp.max(jnp.abs(m_k + m_neg))) == 0.0


@pytest.mark.parametrize("sign", [1.0, -1.0])
@pytest.mark.parametrize("n,block_rows", [
    (1, 256),          # single element, maximal padding
    (97, 2),           # odd size, n far from a lane multiple
    (128 * 2, 2),      # exactly one 128*block_rows tile
    (128 * 2 + 1, 2),  # one element past the tile boundary
    (128 * 2 - 1, 2),  # one element short of it
    (50_000, 256),     # many tiles, ragged tail
])
def test_mask_prng_kernel_ref_parity_padding_boundaries(n, block_rows, sign):
    """mask_prng.py (interpret) vs ref.py over odd sizes and padding
    boundaries (n not a multiple of 128*block_rows), both signs — the
    padded lanes of the last tile must not leak into the unpadded view."""
    from repro.kernels.mask_prng import mask_prng_apply

    g = jax.random.normal(jax.random.fold_in(KEY, n), (n,))
    o_k, m_k = mask_prng_apply(g, 77, sigma=-0.2, sign=sign,
                               block_rows=block_rows, interpret=True)
    o_r, m_r = ref.mask_prng_ref(g, 77, p=-1.0, q=2.0, sigma=-0.2, sign=sign)
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-6)
    assert m_k.shape == g.shape


@pytest.mark.parametrize("n_pairs,nb,k_mask,m", [
    (3, 1, 37, 257),     # odd everything
    (6, 4, 17, 1000),    # blocked layout
    (2, 1, 1, 5),        # minimal
    (5, 2, 129, 4097),   # k_mask one past the lane boundary
    (4, 1, 128, 128),    # exactly one lane row
])
def test_pair_mask_streams_kernel_ref_parity(n_pairs, nb, k_mask, m):
    """The sparse pair-mask kernel (interpret) is bit-identical to
    ref.pair_mask_stream_ref — indices AND values, mixed signs."""
    from repro.kernels.mask_prng import pair_mask_streams

    seeds = (jnp.arange(1, n_pairs + 1, dtype=jnp.uint32)
             * jnp.uint32(2654435761))
    signs = jnp.asarray([(-1.0) ** i for i in range(n_pairs)], jnp.float32)
    ik, vk = pair_mask_streams(seeds, signs, nb=nb, k_mask=k_mask, m=m,
                               interpret=True)
    ir, vr = ref.pair_mask_stream_ref(seeds, signs, nb, k_mask, m,
                                      p=-1.0, q=2.0)
    assert ik.shape == (n_pairs, nb, k_mask)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))
    assert (np.asarray(ik) >= 0).all() and (np.asarray(ik) < m).all()


def test_pair_mask_streams_opposite_signs_cancel_bitwise():
    from repro.kernels.mask_prng import pair_mask_streams

    seeds = jnp.asarray([0xABCD1234, 0xABCD1234], jnp.uint32)
    signs = jnp.asarray([1.0, -1.0], jnp.float32)
    idx, vals = pair_mask_streams(seeds, signs, nb=1, k_mask=50, m=333,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(idx[0]), np.asarray(idx[1]))
    assert float(jnp.max(jnp.abs(vals[0] + vals[1]))) == 0.0


@pytest.mark.parametrize("n,size", [(100, 1000), (700, 257), (2048, 100_000),
                                    (5, 64)])
def test_stream_scatter_add_matches_ref(n, size):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 20))
    # include duplicates, the -1 padding sentinel, and out-of-range indices
    idx = jax.random.randint(k1, (n,), -2, size + 3)
    val = jax.random.normal(k2, (n,))
    out = ops.stream_scatter_add(idx, val, size=size)
    exp = ref.stream_scatter_add_ref(idx, val, size)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


def test_stream_scatter_add_duplicates_accumulate():
    idx = jnp.array([3, 3, 3, 0, 9], jnp.int32)
    val = jnp.array([1.0, 2.0, 4.0, 5.0, -1.0])
    out = ops.stream_scatter_add(idx, val, size=10)
    assert float(out[3]) == 7.0 and float(out[0]) == 5.0
    assert float(out[9]) == -1.0 and float(out.sum()) == 11.0


def test_mask_prng_support_fraction():
    g = jnp.zeros((100_000,))
    _, m = ops.mask_prng_apply(g, seed=7, sigma=-0.5, sign=1.0)
    frac = float(jnp.mean(m != 0))
    assert abs(frac - 0.25) < 0.02  # (sigma - p)/q = 0.25


# --------------------------------------------------- wire-format bit packing
@pytest.mark.parametrize("rows,k,width", [
    (1, 1, 1),       # degenerate single-slot
    (3, 37, 11),     # odd everything
    (5, 64, 32),     # full-word fields
    (2, 33, 17),     # one past a chunk boundary
    (7, 31, 18),     # one short of a chunk boundary
    (4, 256, 4),     # many whole chunks
    (2, 97, 1),      # 1-bit sign stream
    (8, 128, 8),     # exact tile
])
def test_bitpack_rows_kernel_matches_ref(rows, k, width):
    """Pallas pack/unpack (interpret mode) is bit-exact with the ref twin."""
    from repro.kernels import pack

    bits = jax.random.bits(jax.random.fold_in(KEY, rows * 1000 + k),
                           (rows, k), jnp.uint32)
    u = bits >> jnp.uint32(32 - width)
    words_ref = ref.bitpack_rows_ref(u, width)
    words_ker = pack.bitpack_rows(u, width, interpret=True)
    np.testing.assert_array_equal(np.asarray(words_ker),
                                  np.asarray(words_ref))
    back_ref = ref.bitunpack_rows_ref(words_ref, k, width)
    back_ker = pack.bitunpack_rows(words_ker, k, width, interpret=True)
    np.testing.assert_array_equal(np.asarray(back_ref), np.asarray(u))
    np.testing.assert_array_equal(np.asarray(back_ker), np.asarray(u))


@pytest.mark.parametrize("k,width", [(1, 1), (37, 11), (64, 32), (33, 17)])
def test_bitpack_rows_ops_dispatch(k, width):
    """The ops-layer jitted wrappers round-trip through either backend."""
    bits = jax.random.bits(jax.random.fold_in(KEY, k + width), (2, k),
                           jnp.uint32)
    u = bits >> jnp.uint32(32 - width)
    words = ops.bitpack_rows(u, width=width)
    assert words.shape == (2, ref.packed_words(k, width))
    back = ops.bitunpack_rows(words, k=k, width=width)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(u))


def test_packed_words():
    assert ref.packed_words(32, 1) == 1
    assert ref.packed_words(33, 1) == 2
    assert ref.packed_words(1, 32) == 1
    assert ref.packed_words(100, 17) == -(-100 * 17 // 32)
