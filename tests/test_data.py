"""Federated partitioner + synthetic dataset properties."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep; tier-1 must collect without it
from hypothesis import given, settings, strategies as st

from repro.data import (MNIST, client_batches, dirichlet, iid, make_dataset,
                        make_lm_tokens, noniid_label_k)


def _labels(n=2000, seed=0):
    return np.random.RandomState(seed).randint(0, 10, size=n)


@given(n_clients=st.integers(2, 20), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_iid_partition_disjoint_and_complete(n_clients, seed):
    y = _labels()
    parts = iid(y, n_clients, seed=seed)
    allidx = np.concatenate(list(parts.values()))
    assert len(allidx) == len(y)
    assert len(np.unique(allidx)) == len(y)


@given(k=st.integers(1, 10), n_clients=st.integers(2, 20),
       seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_noniid_label_k_property(k, n_clients, seed):
    """Paper's Non-IID-k: every client holds samples from exactly <=k classes
    (k when enough data), and the union covers the dataset."""
    y = _labels()
    parts = noniid_label_k(y, n_clients, k, seed=seed)
    allidx = np.concatenate([p for p in parts.values() if len(p)])
    assert len(np.unique(allidx)) == len(allidx)
    for c, idx in parts.items():
        if len(idx):
            assert len(np.unique(y[idx])) <= k


def test_noniid_4_sees_exactly_4():
    y = _labels(5000)
    parts = noniid_label_k(y, 10, 4, seed=1)
    for idx in parts.values():
        assert len(np.unique(y[idx])) == 4


def test_dirichlet_covers():
    y = _labels()
    parts = dirichlet(y, 10, alpha=0.5, seed=0)
    allidx = np.concatenate(list(parts.values()))
    assert len(np.unique(allidx)) == len(y)


def test_dataset_deterministic_and_learnable():
    x1, y1 = make_dataset(MNIST, 500, seed=3)
    x2, y2 = make_dataset(MNIST, 500, seed=3)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (500, 28, 28, 1)
    # nearest-prototype separability: same-class samples are closer on average
    xf = x1.reshape(500, -1)
    d_same, d_diff = [], []
    for c in range(10):
        m = xf[y1 == c].mean(0)
        d_same.append(np.linalg.norm(xf[y1 == c] - m, axis=1).mean())
        d_diff.append(np.linalg.norm(xf[y1 != c] - m, axis=1).mean())
    assert np.mean(d_same) < np.mean(d_diff)


def test_lm_tokens_structure():
    toks, labels = make_lm_tokens(100, 8, 64, seed=0)
    assert toks.shape == (8, 64) and labels.shape == (8, 64)
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
    assert toks.max() < 100 and toks.min() >= 0


def test_client_batches_shape():
    x, y = make_dataset(MNIST, 300, seed=0)
    xb, yb = client_batches(x, y, np.arange(100), batch=10, steps=5, seed=0)
    assert xb.shape == (5, 10, 28, 28, 1)
    assert yb.shape == (5, 10)
