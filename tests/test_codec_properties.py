"""Property tests for the wire-format codecs (hypothesis-driven).

Separate from test_codecs.py because the module-level importorskip gates the
whole file: the parametrized equivalents there always run; these widen the
input space when hypothesis is available.
"""
import pytest

pytest.importorskip("hypothesis")  # dev-only dep; tier-1 must collect without it

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import codecs
from repro.kernels import ref

NON_F32 = [c for c in codecs.CODECS if c != "f32"]


@settings(max_examples=30, deadline=None)
@given(st.data(), st.integers(min_value=1, max_value=32))
def test_bitpack_rows_roundtrip(data, width):
    """Any (rows, k, width) — odd sizes, padding boundaries — round-trips."""
    rows = data.draw(st.integers(min_value=1, max_value=9))
    k = data.draw(st.integers(min_value=1, max_value=300))
    seed = data.draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    hi = np.uint64(1) << np.uint64(width)
    u = rng.integers(0, int(hi), size=(rows, k), dtype=np.uint64)
    u = u.astype(np.uint32)
    words = ref.bitpack_rows_ref(jnp.asarray(u), width)
    assert words.shape == (rows, ref.packed_words(k, width))
    back = ref.bitunpack_rows_ref(words, k, width)
    np.testing.assert_array_equal(np.asarray(back), u)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_delta_packed_indices_roundtrip(data):
    """Monotone duplicate-free column rows survive delta packing exactly."""
    m = data.draw(st.integers(min_value=2, max_value=5000))
    k = data.draw(st.integers(min_value=1, max_value=min(m, 64)))
    rows = data.draw(st.integers(min_value=1, max_value=4))
    seed = data.draw(st.integers(min_value=0, max_value=2**31 - 1))
    codec = data.draw(st.sampled_from(NON_F32))
    rng = np.random.default_rng(seed)
    cols = np.stack([np.sort(rng.choice(m, size=k, replace=False))
                     for _ in range(rows)]).astype(np.int32)
    qmax = {"int8": 127, "int4": 7, "1bit": 1}[codec]
    lo = 1 if codec == "1bit" else -qmax  # 1bit carries sign only: q in {±1}
    q = rng.integers(lo, qmax + 1, size=(rows, k)).astype(np.int32)
    if codec == "1bit":
        q = np.where(rng.integers(0, 2, size=q.shape) > 0, 1, -1).astype(
            np.int32)
    iw, vw = codecs.pack_stream_rows(jnp.asarray(cols), jnp.asarray(q),
                                     m=m, codec=codec)
    c2, q2 = codecs.unpack_stream_rows(iw, vw, k=k, m=m, codec=codec)
    np.testing.assert_array_equal(np.asarray(c2), cols)
    np.testing.assert_array_equal(np.asarray(q2), q)


@settings(max_examples=30, deadline=None)
@given(st.data(), st.sampled_from(NON_F32))
def test_quantize_dequantize_error_bound(data, codec):
    """Per-row quantization error stays within half a step (or mean|v|)."""
    rows = data.draw(st.integers(min_value=1, max_value=4))
    k = data.draw(st.integers(min_value=1, max_value=64))
    seed = data.draw(st.integers(min_value=0, max_value=2**31 - 1))
    scale = data.draw(st.floats(min_value=1e-3, max_value=1e3))
    rng = np.random.default_rng(seed)
    vals = (rng.normal(size=(rows, k)) * scale).astype(np.float32)
    q, scales = codecs.quantize_rows(jnp.asarray(vals), codec)
    vq = np.asarray(codecs.dequantize_rows(q, scales))
    assert np.isfinite(vq).all()
    if codec == "1bit":
        mean = np.abs(vals).mean(axis=-1, keepdims=True)
        assert (np.abs(vq - vals) <= np.abs(vals) + mean + 1e-5).all()
    else:
        qmax = {"int8": 127, "int4": 7}[codec]
        amax = np.abs(vals).max(axis=-1, keepdims=True)
        assert (np.abs(vq - vals) <= amax / qmax * 0.51 + 1e-7).all()
