"""Batched stream-engine throughput vs the seed per-client aggregation loop.

Thin shim: the measurement moved to ``repro.bench.agg_bench`` (suite key
``agg``, BENCH_agg.json — see EXPERIMENTS.md). This wrapper keeps the legacy
``run(quick)`` row interface for ``benchmarks/run.py`` and the standalone
``--json`` CLI for one deprecation cycle.
"""
from __future__ import annotations


def run(quick: bool = False):
    from repro.bench.agg_bench import rows

    return rows(quick=quick)


def main():
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    if args.json:
        print(json.dumps(
            [{"name": n, "us_per_call": us, "derived": d}
             for n, us, d in rows], indent=2))
    else:
        print("name,us_per_call,derived")
        for n, us, d in rows:
            print(f"{n},{us:.1f},{d}")


if __name__ == "__main__":
    main()
