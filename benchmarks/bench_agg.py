"""Batched stream-engine throughput vs the seed per-client aggregation loop.

Measures one secure-aggregation round for a single leaf at ``n_clients``
simulated clients: error-feedback accumulate -> top-k ∪ mask-support unified
streams -> server scatter-add decode.

  * ``loop``    — the seed implementation shape: an un-jitted Python loop that
    encodes one client at a time (eager XLA dispatches per client) and
    scatter-adds one client's stream at a time into the dense buffer.
  * ``batched`` — the stream engine (core/streams.py): every client encoded in
    one vmapped+jitted program, one fused scatter-add for the whole round.

Emits ``name,us_per_call,derived`` rows via benchmarks/run.py (suite key
``agg``), or a JSON document when run standalone with ``--json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import streams
from repro.core.masks import client_masks
from repro.core.secure_agg import encode_leaf
from repro.core.types import SecureAggConfig, THGSConfig


def _time(fn, reps: int) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _loop_round(grads, residuals, k, thgs, sa, participants, size):
    """The seed path: per-client Python encode loop + per-client scatter."""
    C = len(participants)
    k_mask = sa.k_mask_for(size, C)
    streams_all = []
    for ci, c in enumerate(participants):
        mask = client_masks(sa, c, participants, 0, 0, size, k_mask)
        enc = encode_leaf(grads[ci], residuals[ci], k, thgs, mask)
        streams_all.append(enc.stream)
    dense = jnp.zeros((size,), jnp.float32)
    for s in streams_all:
        dense = dense.at[s.indices].add(s.values / C)
    return dense.block_until_ready()


def _one_size(size: int, n_clients: int, reps: int):
    k = max(1, size // 100)
    thgs = THGSConfig(s0=0.01, alpha=1.0, s_min=0.01, time_varying=False)
    sa = SecureAggConfig(mask_ratio=0.01, seed=7)
    participants = list(range(n_clients))
    key = jax.random.key(0)
    grads = jax.random.normal(key, (n_clients, size))
    residuals = jnp.zeros_like(grads)
    k_mask = sa.k_mask_for(size, n_clients)
    # the production data plane: counter-based pair seeds (repro/secagg),
    # not the legacy jax.random pair_keys path
    pair_seeds, pair_signs = streams.pair_seed_matrix(sa, participants, 0)

    def batched_round():
        st, _ = streams.encode_leaf_batch(
            grads, residuals, k=k, nb=1, m=size, size=size,
            pair_seeds=pair_seeds, pair_signs=pair_signs, k_mask=k_mask,
            mask_p=sa.p, mask_q=sa.q, leaf_id=0)
        return streams.decode_leaf_batch(
            st, nb=1, m=size, size=size).block_until_ready()

    us_loop = _time(lambda: _loop_round(grads, residuals, k, thgs, sa,
                                        participants, size), reps)
    us_batched = _time(batched_round, reps)

    k_total = k + n_clients * k_mask
    stream_mb = n_clients * k_total * 8 / 1e6          # int32 idx + f32 val
    dense_mb = n_clients * size * 4 / 1e6
    speedup = us_loop / us_batched
    return [
        (f"agg/loop_c{n_clients}_n{size}", us_loop,
         f"{n_clients / (us_loop / 1e6):.0f}_clients_per_s"),
        (f"agg/batched_c{n_clients}_n{size}", us_batched,
         f"{n_clients / (us_batched / 1e6):.0f}_clients_per_s"),
        (f"agg/speedup_c{n_clients}_n{size}", 0.0, f"{speedup:.1f}x"),
        (f"agg/bytes_c{n_clients}_n{size}", 0.0,
         f"sparse{stream_mb:.1f}MB_vs_dense{dense_mb:.0f}MB"),
    ]


def run(quick: bool = False):
    # headline: the paper-model regime (financial MLP/VGG leaves, 64k params);
    # the second size shows the top-k-bound tail where both paths converge on
    # the same sort cost
    if quick:
        return _one_size(1 << 14, 8, reps=2)
    rows = _one_size(1 << 16, 32, reps=3)
    rows += _one_size(1 << 20, 32, reps=2)
    return rows


def main():
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    if args.json:
        print(json.dumps(
            [{"name": n, "us_per_call": us, "derived": d}
             for n, us, d in rows], indent=2))
    else:
        print("name,us_per_call,derived")
        for n, us, d in rows:
            print(f"{n},{us:.1f},{d}")


if __name__ == "__main__":
    main()
