"""Shared FL-experiment driver for the paper-scale benchmarks.

Runs the full federated pipeline (repro.core.fedavg) on the synthetic
MNIST/Fashion-MNIST/CIFAR-10 stand-ins with the paper's §5 protocol scaled to a
single CPU core: the client population, Non-IID partitioning, local steps and
batch sizes follow the paper; rounds and dataset sizes are reduced (relative
claims, not absolute accuracies, are what EXPERIMENTS.md validates).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs
from repro.core.fedavg import init_state, run_round
from repro.core.types import FedConfig, SecureAggConfig, THGSConfig
from repro.data import client_batches, iid, make_dataset, noniid_label_k
from repro.data.datasets import SPECS
from repro.models.paper_models import (PAPER_MODELS, accuracy,
                                       cross_entropy_loss)


@dataclasses.dataclass
class RunResult:
    name: str
    accuracies: list
    losses: list
    upload_bits_total: int
    dense_upload_bits_total: int
    rounds: int
    wall_s: float

    @property
    def final_acc(self) -> float:
        return float(np.mean(self.accuracies[-3:])) if self.accuracies else 0.0

    def rounds_to_reach(self, target_acc: float) -> Optional[int]:
        for r, a in enumerate(self.accuracies):
            if a >= target_acc:
                return (r + 1) * max(1, self._eval_every)
        return None

    _eval_every: int = 1


def run_fl(
    model_name: str = "mnist_mlp",
    dataset: str = "mnist",
    *,
    thgs: Optional[THGSConfig],
    sa: SecureAggConfig = SecureAggConfig(enabled=False),
    algorithm: str = "fedavg",
    rounds: int = 30,
    n_clients: int = 20,
    clients_per_round: int = 5,
    noniid_k: Optional[int] = None,
    n_train: int = 4000,
    n_test: int = 800,
    local_steps: int = 5,
    local_batch: int = 50,
    lr: float = 0.05,
    eval_every: int = 3,
    seed: int = 0,
    label: str = "",
) -> RunResult:
    model = PAPER_MODELS[model_name]
    spec = SPECS[dataset]
    x, y = make_dataset(spec, n_train, seed=seed)
    xt, yt = make_dataset(spec, n_test, seed=seed + 1, train=False)
    if noniid_k is None:
        parts = iid(y, n_clients, seed=seed)
    else:
        parts = noniid_label_k(y, n_clients, noniid_k, seed=seed)

    fed = FedConfig(n_clients=n_clients, clients_per_round=clients_per_round,
                    local_steps=local_steps, local_batch=local_batch,
                    local_lr=lr, rounds=rounds, algorithm=algorithm,
                    prox_mu=0.01 if algorithm == "fedprox" else 0.0)
    params = model.init(jax.random.key(seed))
    loss_fn = cross_entropy_loss(model)
    st = init_state(params, fed)

    rs = np.random.RandomState(seed)
    accs, losses = [], []
    t0 = time.time()
    for r in range(rounds):
        chosen = rs.choice(n_clients, clients_per_round, replace=False)
        batches = {}
        for c in chosen:
            xb, yb = client_batches(x, y, parts[int(c)], local_batch,
                                    local_steps, seed=r * 1000 + int(c))
            batches[int(c)] = (jnp.asarray(xb), jnp.asarray(yb))
        st = run_round(st, batches, loss_fn, fed, thgs, sa)
        losses.append(float(np.mean([st.losses[c] for c in batches])))
        if (r + 1) % eval_every == 0:
            accs.append(accuracy(model, st.params, xt, yt))
    res = RunResult(
        name=label or f"{model_name}:{algorithm}"
        f"{':thgs' if thgs else ''}{':sa' if sa.enabled else ''}",
        accuracies=accs,
        losses=losses,
        upload_bits_total=sum(rec.upload_bits for rec in st.comm_log),
        dense_upload_bits_total=sum(rec.dense_upload_bits
                                    for rec in st.comm_log),
        rounds=rounds,
        wall_s=time.time() - t0,
    )
    res._eval_every = eval_every
    return res
