"""Protocol -> repro.sim adapter for the paper-scale benchmarks.

The multi-round driver is ``repro.sim.Simulation`` (DESIGN.md §9); this module
only translates the benchmark modules' protocol kwargs (the paper's §5 setup
scaled to a single CPU core: client population, Non-IID partitioning, local
steps and batch sizes follow the paper; rounds and dataset sizes are reduced)
into a :class:`~repro.sim.SimConfig` and runs it. Relative claims, not
absolute accuracies, are what EXPERIMENTS.md validates.
"""
from __future__ import annotations

from typing import Optional

from repro.core.types import SecureAggConfig, THGSConfig
from repro.sim import SimConfig, SimResult, Simulation


def sim_config(
    model_name: str = "mnist_mlp",
    dataset: str = "mnist",
    *,
    thgs: Optional[THGSConfig],
    sa: SecureAggConfig = SecureAggConfig(enabled=False),
    algorithm: str = "fedavg",
    rounds: int = 30,
    n_clients: int = 20,
    clients_per_round: int = 5,
    noniid_k: Optional[int] = None,
    n_train: int = 4000,
    n_test: int = 800,
    local_steps: int = 5,
    local_batch: int = 50,
    lr: float = 0.05,
    eval_every: int = 3,
    seed: int = 0,
    label: str = "",
) -> SimConfig:
    """The benchmarks' historical protocol-kwarg surface, as a SimConfig."""
    return SimConfig(
        name=label or f"{model_name}:{algorithm}"
        f"{':thgs' if thgs else ''}{':sa' if sa.enabled else ''}",
        model=model_name,
        dataset=dataset,
        partition="iid" if noniid_k is None else "noniid",
        noniid_k=noniid_k if noniid_k is not None else 4,
        n_train=n_train,
        n_test=n_test,
        rounds=rounds,
        n_clients=n_clients,
        clients_per_round=clients_per_round,
        local_steps=local_steps,
        local_batch=local_batch,
        local_lr=lr,
        algorithm=algorithm,
        prox_mu=0.01 if algorithm == "fedprox" else 0.0,
        thgs=thgs,
        sa=sa,
        eval_every=eval_every,
        seed=seed,
    )


def simulate(model_name: str = "mnist_mlp", dataset: str = "mnist",
             **protocol) -> SimResult:
    """Build the SimConfig and run it through the sim engine."""
    return Simulation(sim_config(model_name, dataset, **protocol)).run()
