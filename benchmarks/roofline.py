"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

For each (arch × shape × mesh) JSON in experiments/dryrun/:
    compute term    = FLOPs / (chips × 197e12)
    memory term     = HBM bytes / (chips × 819e9)
    collective term = collective bytes / (chips × 50e9)

Two FLOP/byte sources are reported:
  * analytic — first-principles napkin math from the architecture config and
    input shape (the trustworthy number; documented per family below);
  * hlo — compiled cost_analysis(), with the caveat that XLA counts a
    scan/while body ONCE, so we scale HLO numbers by the known trip counts.

MODEL_FLOPS = 6·N·D (dense train) / 6·N_active·D (MoE) / 2·N·D (prefill) /
2·N_active per token (decode); the ratio MODEL_FLOPS / FLOPs flags
remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro import configs
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.specs import SHAPES, arch_for_shape

PYTHONHASH = None


def active_params(cfg, n_total: int) -> int:
    if cfg.moe is None:
        return n_total
    m = cfg.moe
    # remove the routed experts that are not among top_k (+ keep shared)
    expert_p = 3 * cfg.d_model * m.d_ff_expert
    routed_total = cfg.n_layers * m.n_experts * expert_p
    routed_active = cfg.n_layers * m.top_k * expert_p
    return n_total - routed_total + routed_active


def model_flops(cfg, shape, n_params: int) -> float:
    tokens = shape.global_batch * shape.seq_len
    n_act = active_params(cfg, n_params)
    if shape.kind == "train":
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * tokens
    # decode: one token per sequence + attention over the cache
    flops = 2.0 * n_act * shape.global_batch
    if not cfg.encoder_only and cfg.family not in ("ssm",):
        win = cfg.window or shape.seq_len
        ctx = min(shape.seq_len, win)
        n_attn_layers = cfg.n_layers
        flops += (4.0 * shape.global_batch * ctx * cfg.n_heads * cfg.hd
                  * n_attn_layers)
    return flops


def analytic_hbm_bytes(cfg, shape, n_params: int, fl: bool) -> float:
    """Per-step global HBM traffic estimate (weights + activations + caches)."""
    tokens = shape.global_batch * shape.seq_len
    d = cfg.d_model
    bpe = 2  # bf16
    if shape.kind == "train":
        # fwd+bwd: read params twice, write grads, plus ~14 activation
        # round-trips per token per layer (norm/attn/mlp read+write, remat x2)
        act = 14 * tokens * d * bpe * cfg.n_layers
        return 3 * n_params * bpe + act
    if shape.kind == "prefill":
        act = 8 * tokens * d * bpe * cfg.n_layers
        return n_params * bpe + act
    # decode: weights (active) + full KV/state cache read + one-slot write
    n_act = active_params(cfg, n_params)
    if cfg.family == "ssm" and cfg.xlstm:
        dh = 2 * d // cfg.n_heads
        cache = cfg.n_layers // 2 * shape.global_batch * cfg.n_heads * dh * dh * 4
    elif cfg.family == "hybrid":
        d_inner = cfg.ssm.expand * d
        h = d_inner // cfg.ssm.head_dim
        cache = (cfg.n_layers * shape.global_batch * h * cfg.ssm.d_state
                 * cfg.ssm.head_dim * 4)
        n_super = cfg.n_layers // cfg.shared_attn_every
        cache += (n_super * shape.global_batch * shape.seq_len
                  * cfg.n_kv_heads * cfg.hd * 2 * bpe)
    elif cfg.encoder_only:
        cache = 0
    else:
        win = cfg.window or shape.seq_len
        ctx = min(shape.seq_len, win)
        n_kv_layers = cfg.n_layers
        cache = (n_kv_layers * shape.global_batch * ctx * cfg.n_kv_heads
                 * cfg.hd * 2 * bpe)
    return n_act * bpe + cache


SCAN_TRIP = {  # HLO while-body undercount correction per arch (layers scanned)
    # family -> number of scanned iterations for the dominant loop
}


def n_micro_for(n_params: int) -> int:
    # mirrors launch/dryrun.py's microbatch heuristic
    return 8 if n_params > 50e9 else (4 if n_params > 12e9 else
                                      (2 if n_params > 4e9 else 1))


def scan_correction(cfg, shape, n_params: int) -> float:
    """XLA cost_analysis counts each while body once; approximate the true
    totals by multiplying by the dominant loops' trip counts (layer scans,
    nested inner scans, and the microbatch accumulation scan). Crude — the
    roofline's authoritative terms are the analytic ones; HLO-derived numbers
    are a cross-check."""
    layers = 1.0 if cfg.xlstm else float(cfg.n_layers)
    micro = n_micro_for(n_params) if shape.kind == "train" else 1
    return layers * micro


def load_records(dryrun_dir: str = "experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cfg = arch_for_shape(configs.get(rec["arch"]), SHAPES[rec["shape"]])
    shape = SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    import jax

    from repro.models import transformer as tf

    pshapes = jax.eval_shape(lambda k: tf.init_params(cfg, k),
                             jax.random.key(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(pshapes))

    mf = model_flops(cfg, shape, n_params)
    hbm = analytic_hbm_bytes(cfg, shape, n_params, rec.get("fl", False))
    corr = scan_correction(cfg, shape, n_params)
    coll = rec["collectives"]["total_bytes"] * corr
    hlo_flops = rec["cost"].get("flops", 0.0) * chips * corr

    t_compute = mf / (chips * PEAK_FLOPS_BF16)
    t_memory = hbm / (chips * HBM_BW)
    t_coll = coll / (chips * ICI_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "fl": rec.get("fl", False), "chips": chips,
        "model_flops": mf, "hlo_flops": hlo_flops,
        "useful_ratio": mf / hlo_flops if hlo_flops else float("nan"),
        "hbm_bytes": hbm, "collective_bytes": coll,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bottleneck": bottleneck,
        "mem_per_device_gib": rec["memory"].get(
            "per_device_total_bytes", 0) / 2**30,
    }


def run(quick: bool = False):
    rows = []
    for rec in load_records():
        r = roofline_row(rec)
        if r is None:
            continue
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
            + ("/fl" if r["fl"] else ""),
            r["t_compute_s"] * 1e6,
            f"t_compute={r['t_compute_s']:.4f}s;t_memory={r['t_memory_s']:.4f}s;"
            f"t_collective={r['t_collective_s']:.4f}s;"
            f"bottleneck={r['bottleneck']};"
            f"model_tflops={r['model_flops']/1e12:.1f};"
            f"useful_ratio={r['useful_ratio']:.2f};"
            f"mem_dev={r['mem_per_device_gib']:.2f}GiB"))
    return rows
