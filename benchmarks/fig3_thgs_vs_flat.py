"""Paper Fig. 3: THGS (hierarchical, time-varying) vs conventional flat
sparsification under Non-IID-4/6/8, attenuation beta in {0.2, 0.5, 0.8}.
The paper's claim: THGS >= flat everywhere, and the gap to dense closes as
beta -> 0.8."""
from __future__ import annotations

from benchmarks.common import simulate
from repro.core.types import THGSConfig


def run(quick: bool = False):
    rows = []
    proto = dict(rounds=12 if quick else 20, n_clients=10, clients_per_round=5,
                 n_train=1500 if quick else 3000, n_test=400, eval_every=2)
    noniids = (4,) if quick else (4, 6, 8)
    betas = (0.8,) if quick else (0.2, 0.5, 0.8)
    for k in noniids:
        dense = simulate("mnist_mlp", "mnist", thgs=None, noniid_k=k, **proto)
        rows.append((f"fig3/noniid{k}/dense", dense.wall_s / dense.rounds * 1e6,
                     f"final_acc={dense.final_acc:.3f}"))
        for beta in betas:
            flat = simulate(  # conventional: one global rate, no hierarchy
                "mnist_mlp", "mnist",
                thgs=THGSConfig(s0=0.05, alpha=1.0, s_min=0.05,
                                alpha_t=beta, time_varying=True),
                noniid_k=k, **proto)
            thgs = simulate(  # ours: hierarchical layer schedule (Eq. 1)
                "mnist_mlp", "mnist",
                thgs=THGSConfig(s0=0.08, alpha=0.6, s_min=0.02,
                                alpha_t=beta, time_varying=True),
                noniid_k=k, **proto)
            rows.append((
                f"fig3/noniid{k}/beta={beta}",
                thgs.wall_s / thgs.rounds * 1e6,
                f"flat_acc={flat.final_acc:.3f};thgs_acc={thgs.final_acc:.3f};"
                f"dense_acc={dense.final_acc:.3f};"
                f"thgs_beats_flat={thgs.final_acc >= flat.final_acc - 0.02}"))
    return rows
