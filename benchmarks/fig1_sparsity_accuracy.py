"""Paper Fig. 1 (+Fig. 2): aggregation-model accuracy under sparsification at
s = 1 (dense), 0.1, 0.01, 0.001 — IID and Non-IID."""
from __future__ import annotations

from benchmarks.common import simulate
from repro.core.types import SecureAggConfig, THGSConfig


def run(quick: bool = False):
    rows = []
    proto = dict(rounds=10 if quick else 24, n_clients=10, clients_per_round=5,
                 n_train=1200 if quick else 3000, n_test=400, eval_every=2)
    sweeps = [None, 0.1, 0.01] if quick else [None, 0.1, 0.01, 0.001]
    for noniid in (None, 4):
        tag = "iid" if noniid is None else f"noniid{noniid}"
        for s in sweeps:
            thgs = None if s is None else THGSConfig(
                s0=s, alpha=1.0, s_min=s, time_varying=False)
            r = simulate("mnist_mlp", "mnist", thgs=thgs,
                         sa=SecureAggConfig(enabled=False),
                         noniid_k=noniid, **proto)
            comp = r.ledger.totals("paper")["compression_x"] or 1.0
            rows.append((
                f"fig1/{tag}/s={s if s else 'dense'}",
                r.wall_s / r.rounds * 1e6,
                f"final_acc={r.final_acc:.3f};"
                f"acc_curve={','.join(f'{a:.2f}' for a in r.accuracies)};"
                f"compression_x={comp:.1f}"))
    return rows
