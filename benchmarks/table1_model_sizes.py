"""Paper Table 1: model parameter sizes and update volumes (exact).

Volumes are reported under both bit accountings (core/costs): the paper's
64-bit elements and the float32 TPU wire format the sim ledger also tracks.
"""
import time

import jax

from repro.core import costs
from repro.models.paper_models import PAPER_MODELS, TABLE1_PARAMS
from repro.sim.ledger import mib

# Table 1 "update volume" column: m * 64bit (double-precision accounting)
TABLE1_VOLUMES = {"mnist_mlp": "1.2M", "mnist_cnn": "4.44M",
                  "cifar_mlp": "44.6M", "cifar_vgg16": "112M"}


def run(quick: bool = False):
    rows = []
    for name, model in PAPER_MODELS.items():
        t0 = time.perf_counter()
        p = jax.eval_shape(model.init, jax.random.key(0))
        n = sum(x.size for x in jax.tree_util.tree_leaves(p))
        us = (time.perf_counter() - t0) * 1e6
        dense_mb = mib(costs.PAPER_BITS.dense_bits(n))
        tpu_mb = mib(costs.TPU_BITS.dense_bits(n))
        ok = n == TABLE1_PARAMS[name]
        rows.append((f"table1/{name}", us,
                     f"params={n};published={TABLE1_PARAMS[name]};match={ok};"
                     f"update_volume={dense_mb:.2f}MiB;"
                     f"update_volume_tpu={tpu_mb:.2f}MiB;"
                     f"published_volume={TABLE1_VOLUMES[name]}"))
        assert ok, f"Table 1 mismatch for {name}"
    return rows
