"""Paper Table 1: model parameter sizes and update volumes (exact)."""
import time

import jax

from repro.core import costs
from repro.models.paper_models import PAPER_MODELS, TABLE1_PARAMS

# Table 1 "update volume" column: m * 64bit (double-precision accounting)
TABLE1_VOLUMES = {"mnist_mlp": "1.2M", "mnist_cnn": "4.44M",
                  "cifar_mlp": "44.6M", "cifar_vgg16": "112M"}


def run(quick: bool = False):
    rows = []
    for name, model in PAPER_MODELS.items():
        t0 = time.time()
        p = jax.eval_shape(model.init, jax.random.key(0))
        n = sum(x.size for x in jax.tree_util.tree_leaves(p))
        us = (time.time() - t0) * 1e6
        dense_mb = costs.PAPER_BITS.dense_bits(n) / 8 / 2**20
        ok = n == TABLE1_PARAMS[name]
        rows.append((f"table1/{name}", us,
                     f"params={n};published={TABLE1_PARAMS[name]};match={ok};"
                     f"update_volume={dense_mb:.2f}MiB;"
                     f"published_volume={TABLE1_VOLUMES[name]}"))
        assert ok, f"Table 1 mismatch for {name}"
    return rows
