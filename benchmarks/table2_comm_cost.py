"""Paper Table 2: upload communication cost to reach 95% of the final
convergence accuracy under Non-IID — FedAvg vs FedProx vs ours (THGS + sparse
secure aggregation). The paper's headline: ours = 2.9%-18.9% of FedAvg upload
at sparsity 0.01 (x5.3-x34 compression).

Driven by the repro.sim engine: each arm is one Simulation whose CommLedger
provides the cumulative rounds-to-target upload bits (paper accounting).
"""
from __future__ import annotations

from benchmarks.common import simulate
from repro.core.types import SecureAggConfig, THGSConfig
from repro.sim.ledger import mib


def _protocol(quick):
    return dict(rounds=12 if quick else 28, n_clients=10,
                clients_per_round=5, noniid_k=4,
                n_train=1500 if quick else 4000, n_test=400,
                eval_every=2)


def run(quick: bool = False):
    rows = []
    proto = _protocol(quick)
    for model, dataset in (("mnist_mlp", "mnist"),
                           ("mnist_mlp", "fashion_mnist"),
                           ("mnist_cnn", "mnist")):
        if quick and dataset == "fashion_mnist":
            continue
        runs = {}
        runs["fedavg"] = simulate(model, dataset, thgs=None, **proto)
        runs["fedprox"] = simulate(model, dataset, thgs=None,
                                   algorithm="fedprox", **proto)
        runs["ours"] = simulate(
            model, dataset,
            thgs=THGSConfig(s0=0.05, alpha=0.9, s_min=0.01),
            sa=SecureAggConfig(mask_ratio=0.01), **proto)

        # rounds to reach 95% of the dense final accuracy (Table 2 protocol)
        target = 0.95 * runs["fedavg"].final_acc
        base_r = (runs["fedavg"].rounds_to_reach(target)
                  or runs["fedavg"].rounds)
        base_bits = runs["fedavg"].ledger.upload_bits_through(base_r)
        for name, r in runs.items():
            reach = r.rounds_to_reach(target)
            rounds_used = reach or r.rounds
            bits = r.ledger.upload_bits_through(rounds_used)
            ratio = bits / base_bits
            rows.append((
                f"table2/{model}-{dataset}/{name}",
                r.wall_s / r.rounds * 1e6,
                f"acc={r.final_acc:.3f};rounds_to_95pct={reach};"
                f"upload_MiB={mib(bits):.1f};vs_fedavg={ratio:.3f};"
                f"compression_x={1/max(ratio,1e-9):.1f}"))
    return rows
