"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--quick`` shrinks every run
(used in CI); the default sizes match EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: "
                         "table1,table2,fig1,fig3,roofline,agg")
    args = ap.parse_args()

    from benchmarks import (bench_agg, fig1_sparsity_accuracy,
                            fig3_thgs_vs_flat, roofline, table1_model_sizes,
                            table2_comm_cost)

    suites = {
        "table1": table1_model_sizes.run,
        "table2": table2_comm_cost.run,
        "fig1": fig1_sparsity_accuracy.run,
        "fig3": fig3_thgs_vs_flat.run,
        "roofline": roofline.run,
        "agg": bench_agg.run,
    }
    chosen = (args.only.split(",") if args.only else list(suites))

    print("name,us_per_call,derived")
    failures = 0
    for key in chosen:
        t0 = time.time()
        try:
            rows = suites[key](quick=args.quick)
        except Exception as e:  # keep the suite going; report the failure
            print(f"{key}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}", flush=True)
        print(f"# {key} finished in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
