"""DEPRECATED shim over ``python -m repro.bench --csv``.

The benchmark runner moved into the package (``repro.bench``,
BENCH_*.json + CI gate — see EXPERIMENTS.md). This wrapper keeps the old
``name,us_per_call,derived`` CSV surface and ``--only`` keys working for one
deprecation cycle; switch invocations to::

    PYTHONPATH=src python -m repro.bench --csv [--quick] [--only ...]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: "
                         "table1,table2,fig1,fig3,roofline,agg,round")
    args = ap.parse_args()

    print("benchmarks/run.py is deprecated; use "
          "'PYTHONPATH=src python -m repro.bench --csv' instead",
          file=sys.stderr)
    from repro.bench.__main__ import main as bench_main

    # the historical default suite list (repro.bench alone defaults to the
    # JSON perf suites round+agg)
    only = args.only or "table1,table2,fig1,fig3,roofline,agg"
    argv = ["--csv", "--only", only]
    if args.quick:
        argv.append("--quick")
    raise SystemExit(bench_main(argv))


if __name__ == "__main__":
    main()
