"""Regenerate the §Dry-run / §Roofline markdown tables in EXPERIMENTS.md from
experiments/dryrun/*.json. Usage: PYTHONPATH=src python -m benchmarks.make_experiments_tables
(prints markdown to stdout; EXPERIMENTS.md embeds the output)."""
from __future__ import annotations

from benchmarks.roofline import load_records, roofline_row


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def main():
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    failed = [r for r in recs if r.get("status") == "fail"]

    print("### Dry-run summary\n")
    print(f"- compiled OK: **{len(ok)}**, structural skips: {len(skipped)} "
          f"(encoder-only decode), failures: **{len(failed)}**\n")
    print("| arch | shape | mesh | fl | mem/dev GiB | HLO coll GiB | compile s |")
    print("|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"],
                                       r.get("fl", False))):
        mem = r["memory"].get("per_device_total_bytes", 0)
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {'y' if r.get('fl') else ''} | {fmt_bytes(mem)} "
              f"| {fmt_bytes(r['collectives']['total_bytes'])} "
              f"| {r.get('compile_s', 0):.0f} |")
    for r in skipped:
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} |  | skip "
              f"(encoder-only) |  |  |")

    print("\n### Roofline (single-pod 16x16 unless noted)\n")
    print("| arch | shape | fl | t_compute s | t_memory s | t_coll s "
          "| bottleneck | useful FLOP ratio | mem/dev GiB |")
    print("|---|---|---|---|---|---|---|---|---|")
    for rec in sorted(ok, key=lambda r: (r["arch"], r["shape"],
                                         r.get("fl", False))):
        if rec["mesh"] != "single" and not rec.get("fl"):
            continue
        r = roofline_row(rec)
        if r is None:
            continue
        print(f"| {r['arch']} | {r['shape']}{' (pod)' if rec['mesh']=='pod' else ''} "
              f"| {'y' if r['fl'] else ''} "
              f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
              f"| {r['t_collective_s']:.4f} | {r['bottleneck']} "
              f"| {r['useful_ratio']:.2f} | {r['mem_per_device_gib']:.2f} |")


if __name__ == "__main__":
    main()
